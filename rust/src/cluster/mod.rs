//! Host-side driver: the PULP-cluster view of RedMulE-FT.
//!
//! [`System`] bundles the accelerator, the ECC TCDM and the DMA/L2
//! substrate and plays the role of the RISC-V cores in the paper's flow
//! (§3.3–§3.4):
//!
//! 1. stage the matrices into TCDM (DMA from L2),
//! 2. program the shadowed register-file context — including the
//!    software-computed XOR parity bits — and commit it,
//! 3. start the task and service the accelerator,
//! 4. on interrupt: read + clear the fault-status registers, re-program,
//!    and re-execute (fault-tolerant mode) or abandon the workload
//!    (performance mode).
//!
//! The interrupt contract is honoured exactly: the host only learns about
//! an abort by *sampling the IRQ wire*, which the accelerator asserts for
//! two consecutive cycles so a single transient on the wire cannot hide a
//! real fault (§3.3).

use crate::dma::{Dma, L2Mem};
use crate::fault::{first_fault_cycle, last_fault_cycle, FaultCtx, FaultPlan};
use crate::golden::{
    abft_tolerance_scaled_for, analyze_residuals, correct_from_residual, AbftMismatch,
    GemmProblem, Mat, ResidualVerdict, ABFT_TOL_FACTOR,
};
use crate::redmule::fault_unit::cause;
use crate::redmule::regfile::{
    FLAG_ABFT, FLAG_FT_MODE, FLAG_TILE_RECOVERY, REG_FLAGS, REG_K, REG_M, REG_N, REG_RESUME,
    REG_W_ADDR, REG_X_ADDR, REG_Y_ADDR, REG_Z_ADDR,
};
use crate::redmule::{ExecMode, Protection, RedMule, RedMuleConfig, RunState, TaskLayout};
use crate::tcdm::Tcdm;
use crate::util::digest::Fnv64;
use crate::{Error, Result};

pub(crate) mod exec;

pub use exec::TileEngine;

/// Timeout budget: a run that exceeds `TIMEOUT_FACTOR ×` the fault-free
/// cycle count is classified as hung (§4.2's "Timeout" row).
pub const TIMEOUT_FACTOR: u64 = 20;

/// One-time software cost of computing the register-file parity bits on
/// the cluster cores (§3.2: "limited to a one-time increase of 120 cycles
/// per workload at most").
pub const CONFIG_PARITY_CYCLES: u64 = 120;

/// Maximum automatic re-executions after detected faults. The paper's
/// campaign assumes a single fault per run, so one retry always suffices;
/// the guard bounds the multi-fault sweep runs (N faults can abort up to
/// N attempts before the retries run out and the host abandons).
pub const MAX_RETRIES: u32 = 3;

/// Host cycles of one online-ABFT in-place correction: read the residual
/// bank intersection, one Z read-modify-write, one observation fix-up.
/// Orders of magnitude below any recompute — the whole point of the
/// online variant.
pub const ABFT_CORRECT_CYCLES: u64 = 8;

/// How the host re-executes after a detected fault (§3.3 / §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecoveryPolicy {
    /// The paper's evaluated mechanism: discard everything, re-program,
    /// recompute the full matrix.
    #[default]
    FullRestart,
    /// The paper's §5 future work: resume from the tile latched in the
    /// accelerator's progress register. Sound because committed Z tiles
    /// were verified before storing (output checker + gated writes) and
    /// tiles are idempotent; a conservative (early) resume only redoes
    /// committed work.
    TileLevel,
    /// Online-ABFT in-place correction (`Protection::AbftOnline` only,
    /// after FT-GEMM / online-ABFT GPUs): a single corrupted output
    /// element located by the store-residual intersection is rewritten
    /// in place from the exact bit-plane residual — no recompute at all.
    /// The repaired image is still validated against the carried
    /// checksums; anything the residuals cannot pin down to one element
    /// (multi-error patterns, residual-register upsets, corruptions
    /// upstream of the store network) falls back to the `TileLevel`
    /// row-band recompute.
    InPlaceCorrect,
}

impl RecoveryPolicy {
    /// Stable lowercase name, used by the sweep JSON documents.
    pub fn name(self) -> &'static str {
        match self {
            RecoveryPolicy::FullRestart => "full-restart",
            RecoveryPolicy::TileLevel => "tile-level",
            RecoveryPolicy::InPlaceCorrect => "in-place-correct",
        }
    }
}

/// ABFT bookkeeping of one hosted execution (`Protection::Abft` only).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AbftRunInfo {
    /// Writeback verifications that found a checksum mismatch.
    pub detections: u32,
    /// Recoveries that recomputed only the located row band.
    pub band_recomputes: u32,
    /// Recoveries that fell back to a full re-execution (the corruption
    /// could not be localized to rows — e.g. a corrupted operand that
    /// perturbs data and carried checksum consistently, caught by the
    /// column checks only).
    pub full_restarts: u32,
    /// Single corrupted elements repaired in place from the online
    /// store residuals (`Protection::AbftOnline` +
    /// [`RecoveryPolicy::InPlaceCorrect`] only) — no recompute.
    pub corrections: u32,
}

/// Outcome of one hosted execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostOutcome {
    /// Ran to completion with no detected fault.
    Completed,
    /// One or more aborts were detected and the retry succeeded.
    CompletedAfterRetry,
    /// A fault was detected in performance mode (no redundant compute to
    /// retry from under the paper's §3.4 policy) or retries exhausted.
    Abandoned,
    /// The accelerator never finished within the cycle budget.
    TimedOut,
}

/// Report of one hosted GEMM execution.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub outcome: HostOutcome,
    /// Accelerator cycles across all attempts.
    pub cycles: u64,
    /// Host cycles spent on configuration (incl. parity computation).
    pub config_cycles: u64,
    pub retries: u32,
    /// Fault-status causes accumulated over all aborts.
    pub fault_causes: u32,
    /// True if the host observed the IRQ wire asserted at least once.
    pub irq_seen: bool,
    /// How many of the planned faults landed (multi-fault runs can see a
    /// subset masked; single-fault runs report 0 or 1).
    pub faults_applied: u32,
    /// ABFT verification/recovery bookkeeping (`Some` only on
    /// `Protection::Abft` builds).
    pub abft: Option<AbftRunInfo>,
    /// The Z region read back from TCDM (the data region only on ABFT
    /// builds — carried checksums are stripped).
    pub z: Mat,
}

impl RunReport {
    /// Bit-exact comparison against a golden result.
    pub fn z_matches(&self, golden: &Mat) -> bool {
        self.z.bits() == golden.bits()
    }

    /// True if any planned fault actually hit live state / an exercised
    /// net (false = architecturally masked, e.g. an idle-net transient).
    pub fn fault_applied(&self) -> bool {
        self.faults_applied > 0
    }
}

// ----------------------------------------------- fast-forward reference

/// One snapshot of the fault-free reference execution: the accelerator's
/// complete architectural state and the TCDM's delta vs. the pristine
/// staged image at a checkpoint cycle, plus the rolling state digest the
/// convergence probe compares against.
#[derive(Debug, Clone)]
pub struct RefCheckpoint {
    /// Cycle the snapshot was taken at (a multiple of the interval;
    /// checkpoint `i` sits at cycle `i × interval`, with checkpoint 0
    /// capturing the state right after programming + task start).
    pub cycle: u64,
    pub redmule: RedMule,
    pub tcdm_delta: Vec<(u32, u64)>,
    pub digest: u64,
}

/// The reference writes of one inter-checkpoint segment, recorded by the
/// two-level instrumentation: the cycle-stamped write log (in write
/// order, duplicates included — exactly the TCDM dirty-log appends) and
/// its sorted, de-duplicated word set. Segment `i` covers the cycles
/// `((i-1)·interval, i·interval]` between checkpoints `i-1` and `i`;
/// segment 0 is empty by construction (it pairs with checkpoint 0, taken
/// before the first step).
#[derive(Debug, Clone, Default)]
pub struct SegmentLog {
    /// Sorted, de-duplicated flat word indices of every write in `log`.
    pub writes: Vec<u32>,
    /// `(cycle, flat index, stored codeword after the write)` per write.
    pub log: Vec<(u64, u32, u64)>,
}

impl SegmentLog {
    /// Canonicalize `writes` from the accumulated `log`.
    fn finalize(&mut self) {
        self.writes.clear();
        self.writes.extend(self.log.iter().map(|e| e.1));
        self.writes.sort_unstable();
        self.writes.dedup();
    }
}

/// Two-level instrumentation of a reference run: enough per-cycle
/// information to prove a faulted run has re-converged with the
/// reference at *any* cycle — not only at checkpoint boundaries — so the
/// executor can hand control back to the functional level as soon as the
/// fault window's architectural settling is over.
///
/// The convergence argument (pinned by `tests/twolevel.rs` and the
/// engine-matrix A/B suites): after a checkpoint restore, the faulted
/// state can differ from the reference at cycle `t` only in (a) the
/// accelerator — covered whole by the per-cycle digest — and (b) TCDM
/// words either written by the faulted window (the dirty log past the
/// window watermark) or written by the reference since the restore
/// checkpoint (the segment write-sets). Every other word carries the
/// restore checkpoint's content on both sides. Checking that closed set
/// is therefore a *full-state* equality proof at `t`, and the recorded
/// clean tail substitutes for the remaining cycles bit for bit.
#[derive(Debug, Clone)]
pub struct TwoLevelRef {
    /// Accelerator state digest ([`RedMule::digest64`]) at every cycle
    /// `0..=cycles` of the reference run (index = cycle).
    pub cycle_digests: Vec<u64>,
    /// Per-checkpoint segment logs; `segments.len() == checkpoints.len()`
    /// and `segments[0]` is empty.
    pub segments: Vec<SegmentLog>,
    /// Writes after the last checkpoint, up to task completion.
    pub tail: SegmentLog,
}

/// The instrumented fault-free reference run of one (problem, protection,
/// mode) combination: periodic state checkpoints for fast-forwarding past
/// the identical prefix of every injection, per-checkpoint digests for
/// convergence early-exit, and the recorded clean outcome the early exit
/// substitutes for the simulated tail.
#[derive(Debug, Clone)]
pub struct RefTrace {
    /// Checkpoint spacing in cycles (≥ 1).
    pub interval: u64,
    /// Total fault-free accelerator cycles (the campaign's horizon).
    pub cycles: u64,
    /// Host cycles of the initial `program()` alone.
    pub program_cycles: u64,
    /// Host cycles of the complete clean run (programming plus, on ABFT
    /// builds, the writeback verification).
    pub config_cycles: u64,
    /// The clean run's host-visible result (checksums stripped on ABFT).
    pub z: Mat,
    /// ABFT bookkeeping of the clean run (`Some(default)` on ABFT builds).
    pub abft: Option<AbftRunInfo>,
    /// Checkpoints in cycle order: `checkpoints[i].cycle == i × interval`.
    pub checkpoints: Vec<RefCheckpoint>,
    /// Two-level instrumentation (`Some` only when recorded with
    /// [`System::record_reference_two_level`]). A trace carrying it is a
    /// strict superset of the plain recording — checkpoints, digests and
    /// the clean outcome are identical.
    pub two_level: Option<TwoLevelRef>,
}

impl RefTrace {
    /// The report a clean (no live faults) hosted run would produce —
    /// exactly what [`System::run_staged_with_faults`] returns for an
    /// empty plan list on identically staged state.
    pub fn clean_report(&self) -> RunReport {
        RunReport {
            outcome: HostOutcome::Completed,
            cycles: self.cycles,
            config_cycles: self.config_cycles,
            retries: 0,
            fault_causes: 0,
            irq_seen: false,
            faults_applied: 0,
            abft: self.abft,
            z: self.z.clone(),
        }
    }

    /// The checkpoint to resume from for a fault plan whose earliest
    /// strike is at `first_cycle`: the last checkpoint strictly before
    /// that cycle, so the restored prefix is bit-identical to what the
    /// direct path would have simulated.
    pub fn checkpoint_before(&self, first_cycle: u64) -> &RefCheckpoint {
        &self.checkpoints[self.checkpoint_index_before(first_cycle)]
    }

    /// Index form of [`RefTrace::checkpoint_before`] (the two-level
    /// engine keys its segment write-sets by checkpoint index).
    pub fn checkpoint_index_before(&self, first_cycle: u64) -> usize {
        let idx = (first_cycle.saturating_sub(1) / self.interval) as usize;
        idx.min(self.checkpoints.len() - 1)
    }
}

/// Whether a recovery policy is meaningful on a given hardware build —
/// the sweep engine rejects grid cells pairing them incompatibly.
///
/// * [`RecoveryPolicy::FullRestart`] needs nothing: the host can always
///   discard and re-run (in performance mode without detection it simply
///   never triggers).
/// * [`RecoveryPolicy::TileLevel`] needs *some* detection hardware to
///   latch a progress tile worth resuming from (control checkers,
///   per-CE checkers, ECC data protection or ABFT checksums).
/// * [`RecoveryPolicy::InPlaceCorrect`] needs the online-ABFT store
///   residuals — only [`Protection::AbftOnline`] builds tap them.
pub fn recovery_valid(protection: Protection, recovery: RecoveryPolicy) -> bool {
    match recovery {
        RecoveryPolicy::FullRestart => true,
        RecoveryPolicy::TileLevel => {
            protection.has_control_protection()
                || protection.has_per_ce_checkers()
                || protection.has_data_protection()
                || protection.has_abft_checksums()
        }
        RecoveryPolicy::InPlaceCorrect => protection.has_online_abft(),
    }
}

/// Combined convergence digest: accelerator state + TCDM contents (as a
/// delta against the pristine staged image, so equal contents hash equal
/// regardless of write history). Runs through the TCDM's reusable
/// digest scratch, so the per-checkpoint probes of the fast-forward hot
/// loop allocate nothing; the byte stream (and therefore the digest
/// value) is identical to hashing the materialized delta.
fn ff_digest(redmule: &RedMule, tcdm: &mut Tcdm, pristine: &Tcdm) -> u64 {
    let mut h = Fnv64::new();
    redmule.digest_into(&mut h);
    tcdm.digest_delta_scratch(pristine, &mut h);
    h.finish()
}

/// [`ff_digest`] over an already-computed TCDM delta (the reference
/// recorder keeps the delta for the checkpoint anyway — one scan serves
/// both the snapshot and its digest).
fn ff_digest_with_delta(redmule: &RedMule, delta: &[(u32, u64)]) -> u64 {
    let mut h = Fnv64::new();
    redmule.digest_into(&mut h);
    Tcdm::digest_delta_entries(delta, &mut h);
    h.finish()
}

/// How a functional-level resume probes for re-convergence with the
/// reference (see [`exec`] for the two-level executor built on top).
#[derive(Debug, Clone, Copy)]
pub(crate) enum ResumeProbe {
    /// PR-3 fast-forward behavior: hash the *complete* state
    /// (accelerator + TCDM delta) at each checkpoint boundary and
    /// compare against the checkpoint digest.
    FullDigest,
    /// Two-level engine: compare the accelerator's own digest against
    /// the per-cycle reference digest, then prove TCDM equality over
    /// the closed set of possibly-differing words (fault-window writes
    /// ∪ reference segment write-sets). Probes fire at checkpoint
    /// boundaries and, once past `window_end`, every
    /// [`exec::EARLY_PROBE_STRIDE`] cycles — convergence is detected
    /// within a few cycles of architectural settling instead of up to
    /// an interval later.
    Window {
        /// Index of the restored checkpoint.
        base_idx: usize,
        /// TCDM dirty-log length right after the checkpoint delta was
        /// applied: everything past it is a fault-window write.
        window_mark: usize,
        /// End of the planned cycle-accurate window (last plan cycle +
        /// settling); early probes start beyond it.
        window_end: u64,
    },
}

/// Resume parameters of a fast-forwarded first attempt (see
/// [`System::run_staged_with_faults_ff`]).
pub(crate) struct FfResume<'a> {
    trace: &'a RefTrace,
    pristine: &'a Tcdm,
    /// No plan can fire after this cycle, so convergence probes (and the
    /// retry shortcut) are meaningful beyond it.
    last_plan_cycle: u64,
    /// No plan strikes the register file: the one state element a
    /// `FullRestart` re-program does not fully rewrite (only the 9 task
    /// words of the newly-active context are written, and only regfile
    /// SEUs can corrupt the rest — everything else is reset by the
    /// interrupt service + `start()`).
    regfile_untouched: bool,
    /// Convergence probe flavor (functional backend selection).
    probe: ResumeProbe,
}

/// The cluster: accelerator + memory substrate + host logic.
#[derive(Debug)]
pub struct System {
    pub redmule: RedMule,
    pub tcdm: Tcdm,
    pub l2: L2Mem,
    pub dma: Dma,
    /// Base TCDM address for staged tasks.
    task_base: u32,
    /// Re-execution policy after detected faults.
    pub recovery: RecoveryPolicy,
    /// ABFT verification tolerance safety factor (see
    /// [`crate::golden::ABFT_TOL_FACTOR`]; the sweep engine varies it).
    pub abft_tol_factor: f64,
    /// Scratch for the two-level convergence probe's candidate word set
    /// (reused across probes — the injection hot loop allocates nothing).
    tl_cand: Vec<u32>,
    /// Scratch for the partial-segment write map: `(flat index, sequence
    /// number, codeword)`, sorted so the latest write per word wins.
    tl_partial: Vec<(u32, u32, u64)>,
}

impl System {
    pub fn new(cfg: RedMuleConfig, protection: Protection) -> Self {
        Self::with_tcdm(cfg, protection, Tcdm::cluster_default())
    }

    /// A smaller TCDM for tests that exercise address wrapping.
    pub fn with_tcdm(cfg: RedMuleConfig, protection: Protection, tcdm: Tcdm) -> Self {
        Self {
            redmule: RedMule::new(cfg, protection),
            tcdm,
            l2: L2Mem::new(1 << 20),
            dma: Dma::new(),
            task_base: 0x100,
            recovery: RecoveryPolicy::FullRestart,
            abft_tol_factor: ABFT_TOL_FACTOR,
            tl_cand: Vec::new(),
            tl_partial: Vec::new(),
        }
    }

    /// Select the post-detection re-execution policy.
    pub fn with_recovery(mut self, policy: RecoveryPolicy) -> Self {
        self.recovery = policy;
        self
    }

    /// Override the ABFT verification tolerance safety factor.
    pub fn with_abft_tolerance(mut self, factor: f64) -> Self {
        self.abft_tol_factor = factor;
        self
    }

    /// Adopt a pristine staged TCDM image in place: power-on-reset the
    /// accelerator, `copy_from_slice` the image into the existing TCDM
    /// buffers, and (re-)enable dirty tracking — the zero-allocation
    /// counterpart of `sys.tcdm = pristine.clone()` that the campaign
    /// workers and the sweep's work-stealing scheduler run between
    /// batches. After the call the System is bit-identical to a freshly
    /// constructed one that staged the same workload (modulo the shared
    /// L2/DMA substrate, which the injection loop never touches).
    pub fn restore_from(&mut self, pristine: &Tcdm) {
        self.redmule.reset();
        self.tcdm.copy_state_from(pristine);
        if !self.tcdm.dirty_tracking_enabled() {
            self.tcdm.enable_dirty_tracking();
        }
    }

    /// Rebuild the accelerator for a different hardware build, keeping
    /// the TCDM and L2 allocations. Worker threads that hop between
    /// campaign cells of different geometries/protections (the sweep's
    /// grid-wide scheduler) reconfigure one long-lived System instead of
    /// constructing a fresh one per cell. Recovery policy and ABFT
    /// tolerance are left untouched — set the public fields per cell.
    pub fn reconfigure(&mut self, cfg: RedMuleConfig, protection: Protection) {
        self.redmule = RedMule::new(cfg, protection);
    }

    pub fn protection(&self) -> Protection {
        self.redmule.protection
    }

    /// Stage a GEMM problem into TCDM (DMA in from L2) and return its
    /// layout. Z is zeroed so stale results can't alias a correct one.
    /// A task that does not fit in TCDM is a structured [`Error::Sim`],
    /// not a panic — sweep grids probe the capacity boundary routinely
    /// and an exactly-fitting task is legal.
    ///
    /// On `Protection::Abft` builds the host transparently stages the
    /// ABFT-augmented problem (checksum row of X, checksum column of W,
    /// bordered Y): the returned layout has `m+1` rows and `k+1` columns
    /// and the accelerator carries the checksums through the GEMM as one
    /// extra row/column of tiles. [`System::run_staged_with_fault`]
    /// verifies and strips them at writeback.
    pub fn stage(&mut self, p: &GemmProblem) -> Result<TaskLayout> {
        if self.protection().has_abft_checksums() {
            let augmented = p.augment_abft();
            return self.stage_inner(&augmented);
        }
        self.stage_inner(p)
    }

    fn stage_inner(&mut self, p: &GemmProblem) -> Result<TaskLayout> {
        let spec = p.spec;
        let layout = TaskLayout::contiguous(
            self.task_base,
            spec.m as u32,
            spec.n as u32,
            spec.k as u32,
        );
        // Fit check against the *end address* (base + footprint), and
        // inclusive: a task whose last byte lands exactly on the capacity
        // boundary fits. (The pre-PR-2 check compared the footprint alone
        // against the capacity with `<`: it ignored the staging base, so
        // a task with footprint just under the TCDM size slipped past the
        // check and blew the out-of-range `assert!` inside `Tcdm::locate`
        // during staging — and the boundary itself was off by one.)
        let end = layout.x_addr as usize + layout.footprint() as usize;
        if end > self.tcdm.size_bytes() {
            return Err(Error::Sim(format!(
                "task does not fit in TCDM: ({}x{}x{}) at base 0x{:X} ends at \
                 0x{end:X}, capacity {} bytes",
                layout.m,
                layout.n,
                layout.k,
                layout.x_addr,
                self.tcdm.size_bytes()
            )));
        }
        // Host writes the matrices to L2, DMA moves them into TCDM. DMA
        // lengths are in bytes, word-padded (the regions are 4-aligned and
        // disjoint, so the pad bytes never alias the next matrix).
        let word_pad = |elems: usize| (2 * elems).div_ceil(4) * 4;
        self.l2.write_fp16_slice(layout.x_addr as usize, &p.x.data);
        self.dma.copy_in(
            &self.l2,
            layout.x_addr as usize,
            &mut self.tcdm,
            layout.x_addr,
            word_pad(p.x.data.len()),
        );
        self.l2.write_fp16_slice(layout.w_addr as usize, &p.w.data);
        self.dma.copy_in(
            &self.l2,
            layout.w_addr as usize,
            &mut self.tcdm,
            layout.w_addr,
            word_pad(p.w.data.len()),
        );
        self.l2.write_fp16_slice(layout.y_addr as usize, &p.y.data);
        self.dma.copy_in(
            &self.l2,
            layout.y_addr as usize,
            &mut self.tcdm,
            layout.y_addr,
            word_pad(p.y.data.len()),
        );
        let zeros = vec![crate::fp::Fp16::ZERO; spec.m * spec.k];
        self.tcdm.write_fp16_slice(layout.z_addr, &zeros);
        Ok(layout)
    }

    /// Checksum of the X/W operand images *at rest in TCDM* under
    /// `layout` — the ABFT input-staging check. Reading goes through the
    /// same TCDM port the accelerator fetches from, so anything that
    /// corrupted the staged image after DMA (an SEU in a TCDM word, a
    /// botched DMA burst) changes this digest.
    pub fn staged_input_digest(&mut self, layout: &TaskLayout) -> u64 {
        let x = self
            .tcdm
            .read_fp16_slice(layout.x_addr, (layout.m * layout.n) as usize);
        let w = self
            .tcdm
            .read_fp16_slice(layout.w_addr, (layout.n * layout.k) as usize);
        let mut h = Fnv64::new();
        for v in x.iter().chain(w.iter()) {
            h.write_u16(v.to_bits());
        }
        h.finish()
    }

    /// The digest [`System::staged_input_digest`] must report for a
    /// correctly staged `p` on this build (ABFT builds stage the
    /// augmented problem, so the expected image is augmented too).
    pub fn expected_input_digest(&self, p: &GemmProblem) -> u64 {
        let digest = |x: &Mat, w: &Mat| {
            let mut h = Fnv64::new();
            for v in x.data.iter().chain(w.data.iter()) {
                h.write_u16(v.to_bits());
            }
            h.finish()
        };
        if self.protection().has_abft_checksums() {
            let a = p.augment_abft();
            digest(&a.x, &a.w)
        } else {
            digest(&p.x, &p.w)
        }
    }

    /// Verify the staged X/W images at rest in TCDM before compute — the
    /// input-staging half of the ABFT story (the writeback checksums
    /// only cover the compute/store path; a corrupted *input* image
    /// yields a wrong result whose checksums are self-consistent).
    /// Opt-in: the default campaign path never calls this, so all
    /// pinned streams and baselines are untouched.
    pub fn verify_staged_inputs(&mut self, p: &GemmProblem, layout: &TaskLayout) -> bool {
        self.staged_input_digest(layout) == self.expected_input_digest(p)
    }

    /// Repair a corrupted staged input image by re-running the X/W DMA
    /// transfers (Y and Z are left untouched). Pairs with
    /// [`System::verify_staged_inputs`]: detect, restage, re-verify.
    pub fn restage_inputs(&mut self, p: &GemmProblem, layout: &TaskLayout) -> Result<()> {
        let (x, w) = if self.protection().has_abft_checksums() {
            let a = p.augment_abft();
            (a.x.data, a.w.data)
        } else {
            (p.x.data.clone(), p.w.data.clone())
        };
        let word_pad = |elems: usize| (2 * elems).div_ceil(4) * 4;
        self.l2.write_fp16_slice(layout.x_addr as usize, &x);
        self.dma.copy_in(
            &self.l2,
            layout.x_addr as usize,
            &mut self.tcdm,
            layout.x_addr,
            word_pad(x.len()),
        );
        self.l2.write_fp16_slice(layout.w_addr as usize, &w);
        self.dma.copy_in(
            &self.l2,
            layout.w_addr as usize,
            &mut self.tcdm,
            layout.w_addr,
            word_pad(w.len()),
        );
        Ok(())
    }

    /// Program the shadowed register-file context for `layout` and commit
    /// it. Returns the host cycles spent (parity computation included for
    /// protected builds).
    pub fn program(&mut self, layout: &TaskLayout, mode: ExecMode) -> u64 {
        self.program_with_resume(layout, mode, None)
    }

    /// Like [`System::program`], optionally arming tile-level recovery at
    /// `resume = (mt, kt)`.
    pub fn program_with_resume(
        &mut self,
        layout: &TaskLayout,
        mode: ExecMode,
        resume: Option<(u16, u16)>,
    ) -> u64 {
        let mut flags = match mode {
            ExecMode::FaultTolerant => FLAG_FT_MODE,
            ExecMode::Performance => 0,
        };
        if self.redmule.protection.has_abft_checksums() {
            flags |= FLAG_ABFT;
        }
        let resume_word = match resume {
            Some((mt, kt)) => {
                flags |= FLAG_TILE_RECOVERY;
                (u32::from(mt) << 16) | u32::from(kt)
            }
            None => 0,
        };
        self.redmule.regfile.host_program(&[
            (REG_X_ADDR, layout.x_addr),
            (REG_W_ADDR, layout.w_addr),
            (REG_Y_ADDR, layout.y_addr),
            (REG_Z_ADDR, layout.z_addr),
            (REG_M, layout.m),
            (REG_N, layout.n),
            (REG_K, layout.k),
            (REG_FLAGS, flags),
            (REG_RESUME, resume_word),
        ]);
        self.redmule.regfile.commit();
        if self.redmule.protection.has_control_protection() {
            CONFIG_PARITY_CYCLES
        } else {
            8 // plain config writes
        }
    }

    /// Program a row-band sub-task of an ABFT layout: rows `r0..=r1` of
    /// the augmented matrices, all columns. X/Y/Z rows are contiguous in
    /// the row-major layout, so the band is itself a smaller contiguous
    /// GEMM at offset base addresses and goes through the ordinary
    /// programming sequence.
    fn program_abft_band(&mut self, layout: &TaskLayout, mode: ExecMode, r0: u32, r1: u32) -> u64 {
        let band = TaskLayout {
            x_addr: layout.x_addr + r0 * layout.n * 2,
            w_addr: layout.w_addr,
            y_addr: layout.y_addr + r0 * layout.k * 2,
            z_addr: layout.z_addr + r0 * layout.k * 2,
            m: r1 - r0 + 1,
            n: layout.n,
            k: layout.k,
        };
        self.program(&band, mode)
    }

    /// ABFT writeback verification: compare the checksum unit's observed
    /// row/column sums against the carried checksums in the Z region.
    /// After a band recompute (`band = Some((r0, r1))`) only those rows
    /// are checked — their carried checksums regenerated with the band,
    /// while the column accumulations are stale by construction.
    fn abft_check(&mut self, layout: &TaskLayout, band: Option<(u32, u32)>) -> AbftMismatch {
        let m_aug = layout.m as usize;
        let k_aug = layout.k as usize;
        let n = layout.n as usize;
        let k_data = k_aug - 1;
        let mut mm = AbftMismatch::default();
        let (r0, r1) = match band {
            Some((a, b)) => (a as usize, b as usize),
            None => (0, m_aug - 1),
        };
        for i in r0..=r1 {
            let addr = layout.z_addr + ((i * k_aug + k_data) * 2) as u32;
            let carried = self.tcdm.read_fp16(addr).0;
            let unit_row = i - r0; // band sub-tasks index rows from 0
            let obs = self.redmule.abft.row_sum(unit_row);
            let abs = self.redmule.abft.row_abs(unit_row);
            let tol = abft_tolerance_scaled_for(
                self.redmule.cfg.format,
                self.abft_tol_factor,
                n,
                k_data,
                abs,
            );
            let dev = (obs - carried.to_f64()).abs();
            if !carried.is_finite() || !dev.is_finite() || dev > tol {
                mm.rows.push(i);
            }
        }
        if band.is_none() {
            for j in 0..k_data {
                let addr = layout.z_addr + (((m_aug - 1) * k_aug + j) * 2) as u32;
                let carried = self.tcdm.read_fp16(addr).0;
                let obs = self.redmule.abft.col_sum(j);
                let abs = self.redmule.abft.col_abs(j);
                let tol = abft_tolerance_scaled_for(
                    self.redmule.cfg.format,
                    self.abft_tol_factor,
                    n,
                    m_aug - 1,
                    abs,
                );
                let dev = (obs - carried.to_f64()).abs();
                if !carried.is_finite() || !dev.is_finite() || dev > tol {
                    mm.cols.push(j);
                }
            }
        }
        mm
    }

    /// The host-visible result: on ABFT builds the carried checksum
    /// row/column are stripped, leaving the data region.
    fn final_z(&mut self, layout: &TaskLayout) -> Mat {
        let z = self.read_z(layout);
        if self.protection().has_abft_checksums() && z.rows >= 2 && z.cols >= 2 {
            let (data, _, _) = crate::golden::split_abft_z(&z);
            data
        } else {
            z
        }
    }

    /// Execute a staged + programmed task to completion, abort, or
    /// timeout. Returns (aborted, cycles_used, irq_seen).
    fn execute_attempt(
        &mut self,
        ctx: &mut FaultCtx,
        budget: u64,
    ) -> (bool, u64, bool) {
        self.redmule.start();
        let start_cycle = self.redmule.cycle;
        let mut irq_seen = false;
        loop {
            self.redmule.step(&mut self.tcdm, ctx);
            // The host samples the IRQ wire every cycle (§3.3: asserted
            // for two consecutive cycles so one transient cannot hide it).
            irq_seen |= self.redmule.irq();
            match self.redmule.state() {
                RunState::Done => return (false, self.redmule.cycle - start_cycle, irq_seen),
                RunState::Aborted => return (true, self.redmule.cycle - start_cycle, irq_seen),
                _ => {}
            }
            if self.redmule.cycle - start_cycle > budget {
                return (false, self.redmule.cycle - start_cycle, irq_seen);
            }
        }
    }

    /// Continue a restored first attempt to completion, abort, timeout or
    /// convergence. Returns (aborted, cycles_used, irq_seen, converged).
    ///
    /// Mirrors [`System::execute_attempt`] with two differences: the
    /// checkpoint restored the accelerator *mid-task*, so there is no
    /// `start()` and the attempt logically began at cycle 0 (the skipped
    /// prefix counts as executed — budget accounting and the returned
    /// cycle count match the direct path exactly); and once every plan's
    /// cycle is behind, the state digest is probed against the reference
    /// at each checkpoint boundary.
    fn execute_resumed_attempt(
        &mut self,
        ctx: &mut FaultCtx,
        budget: u64,
        ff: &FfResume<'_>,
    ) -> (bool, u64, bool, bool) {
        let mut irq_seen = false;
        loop {
            self.redmule.step(&mut self.tcdm, ctx);
            irq_seen |= self.redmule.irq();
            match self.redmule.state() {
                RunState::Done => return (false, self.redmule.cycle, irq_seen, false),
                RunState::Aborted => return (true, self.redmule.cycle, irq_seen, false),
                _ => {}
            }
            if self.redmule.cycle > budget {
                return (false, self.redmule.cycle, irq_seen, false);
            }
            let cycle = self.redmule.cycle;
            if cycle > ff.last_plan_cycle {
                match ff.probe {
                    ResumeProbe::FullDigest => {
                        if cycle % ff.trace.interval == 0 {
                            let idx = (cycle / ff.trace.interval) as usize;
                            if let Some(cp) = ff.trace.checkpoints.get(idx) {
                                if cp.cycle == cycle
                                    && ff_digest(&self.redmule, &mut self.tcdm, ff.pristine)
                                        == cp.digest
                                {
                                    return (false, self.redmule.cycle, irq_seen, true);
                                }
                            }
                        }
                    }
                    ResumeProbe::Window {
                        base_idx,
                        window_mark,
                        window_end,
                    } => {
                        let boundary = cycle % ff.trace.interval == 0;
                        let early =
                            cycle > window_end && cycle % exec::EARLY_PROBE_STRIDE == 0;
                        if (boundary || early)
                            && self.tl_converged(
                                ff.trace,
                                ff.pristine,
                                base_idx,
                                window_mark,
                                cycle,
                            )
                        {
                            return (false, self.redmule.cycle, irq_seen, true);
                        }
                    }
                }
            }
        }
    }

    /// Two-level convergence proof at `cycle`: true iff the simulated
    /// state is bit-identical to the reference run's state at the same
    /// cycle, established without a full-state scan.
    ///
    /// Fast reject first — the accelerator digest at `cycle` must match
    /// the recorded per-cycle digest (one accelerator hash, no TCDM
    /// traffic; while the fault is still settling this almost always
    /// differs). Then TCDM equality is proven over the closed candidate
    /// set of words that *can* differ: writes of the faulted window (the
    /// dirty log past `window_mark`) plus every word the reference wrote
    /// since the restored checkpoint (full segment write-sets, and the
    /// partial segment's log truncated to `cycle`). Every word outside
    /// that set carries the restored checkpoint's content on both sides,
    /// so set equality ⇒ full-state equality ⇒ the remaining cycles
    /// replay the recorded clean tail bit for bit.
    fn tl_converged(
        &mut self,
        trace: &RefTrace,
        pristine: &Tcdm,
        base_idx: usize,
        window_mark: usize,
        cycle: u64,
    ) -> bool {
        let Some(tl) = trace.two_level.as_ref() else {
            return false;
        };
        // Past the reference horizon the run cannot converge (the
        // reference already finished); only Done/abort/timeout remain.
        let Some(&acc_digest) = tl.cycle_digests.get(cycle as usize) else {
            return false;
        };
        if self.redmule.digest64() != acc_digest {
            return false;
        }
        let n_cp = trace.checkpoints.len();
        // Segments fully elapsed at `cycle` (segment i covers
        // ((i-1)·interval, i·interval]); anything beyond contributes only
        // its log entries at cycles ≤ `cycle`.
        let full_end = ((cycle / trace.interval) as usize).min(n_cp - 1);
        let mut cand = std::mem::take(&mut self.tl_cand);
        let mut partial = std::mem::take(&mut self.tl_partial);
        cand.clear();
        partial.clear();
        cand.extend_from_slice(self.tcdm.dirty_log_since(window_mark));
        for seg in &tl.segments[(base_idx + 1).min(n_cp)..=full_end] {
            cand.extend_from_slice(&seg.writes);
        }
        let partial_log: &[(u64, u32, u64)] = if full_end + 1 < n_cp {
            &tl.segments[full_end + 1].log
        } else {
            &tl.tail.log
        };
        for (seq, &(c, idx, cw)) in partial_log.iter().enumerate() {
            if c <= cycle {
                partial.push((idx, seq as u32, cw));
                cand.push(idx);
            }
        }
        partial.sort_unstable();
        cand.sort_unstable();
        cand.dedup();
        let base_cp = &trace.checkpoints[full_end];
        let mut converged = true;
        for &w in cand.iter() {
            // Reference value of word `w` at `cycle`: the latest partial-
            // segment write ≤ `cycle` wins, else the last full checkpoint's
            // delta entry, else the pristine staged codeword.
            let p = partial.partition_point(|e| e.0 <= w);
            let expect = if p > 0 && partial[p - 1].0 == w {
                partial[p - 1].2
            } else {
                match base_cp.tcdm_delta.binary_search_by_key(&w, |e| e.0) {
                    Ok(i) => base_cp.tcdm_delta[i].1,
                    Err(_) => pristine.raw_codeword_flat(w),
                }
            };
            if self.tcdm.raw_codeword_flat(w) != expect {
                converged = false;
                break;
            }
        }
        self.tl_cand = cand;
        self.tl_partial = partial;
        converged
    }

    /// Run the instrumented fault-free reference execution for the
    /// fast-forward engine: program + start the staged task, step it clean
    /// to completion, and snapshot the complete architectural state (plus
    /// the TCDM delta vs. `pristine`) every `interval` cycles.
    ///
    /// Preconditions (the campaign engine establishes them): the task is
    /// staged at `layout`, `pristine` is a clone of the staged TCDM, the
    /// accelerator is reset, and dirty tracking is enabled. An abort or a
    /// timeout of the fault-free run means the build is broken and is a
    /// hard error, since every fast-forwarded classification would
    /// inherit it. `Ok(None)` is the one soft case: an ABFT build whose
    /// verification tolerance is at/below the FP16 rounding bound flags
    /// even the fault-free run — its clean trajectory ends in a host
    /// retry, so there is no simple recorded tail to substitute and the
    /// caller must fall back to the direct engine.
    ///
    /// `interval = 0` selects the auto spacing: `nominal / 16`, clamped
    /// to `[8, 256]` cycles.
    pub fn record_reference(
        &mut self,
        layout: &TaskLayout,
        pristine: &Tcdm,
        mode: ExecMode,
        interval: u64,
    ) -> Result<Option<RefTrace>> {
        self.record_reference_inner(layout, pristine, mode, interval, false)
    }

    /// [`System::record_reference`] with the two-level instrumentation
    /// enabled: additionally records the accelerator digest at *every*
    /// cycle and the cycle-stamped TCDM write log per inter-checkpoint
    /// segment (`RefTrace::two_level = Some(..)`), so
    /// [`System::run_staged_with_faults_tl`] can prove re-convergence
    /// mid-segment instead of waiting for the next checkpoint boundary.
    /// Checkpoints, digests and the recorded clean outcome are identical
    /// to the plain recording — a two-level trace is a strict superset.
    pub fn record_reference_two_level(
        &mut self,
        layout: &TaskLayout,
        pristine: &Tcdm,
        mode: ExecMode,
        interval: u64,
    ) -> Result<Option<RefTrace>> {
        self.record_reference_inner(layout, pristine, mode, interval, true)
    }

    fn record_reference_inner(
        &mut self,
        layout: &TaskLayout,
        pristine: &Tcdm,
        mode: ExecMode,
        interval: u64,
        two_level: bool,
    ) -> Result<Option<RefTrace>> {
        let program_cycles = self.program(layout, mode);
        let mut config_cycles = program_cycles;
        self.redmule.start();
        let nominal = self.redmule.nominal_cycles().max(1);
        let interval = if interval == 0 {
            (nominal / 16).clamp(8, 256)
        } else {
            interval
        };
        let budget = nominal * TIMEOUT_FACTOR;
        let mut ctx = FaultCtx::clean();
        let mut checkpoints = Vec::with_capacity((nominal / interval + 2) as usize);
        let snap = |redmule: &RedMule, tcdm: &Tcdm| {
            let tcdm_delta = tcdm.dirty_delta(pristine);
            let digest = ff_digest_with_delta(redmule, &tcdm_delta);
            RefCheckpoint {
                cycle: redmule.cycle,
                redmule: redmule.clone(),
                tcdm_delta,
                digest,
            }
        };
        // Two-level instrumentation: per-cycle accelerator digests
        // (index = cycle) and the cycle-stamped write log of the current
        // inter-checkpoint segment. Segment 0 pairs with checkpoint 0 and
        // is empty by construction.
        let mut cycle_digests: Vec<u64> = Vec::new();
        let mut segments: Vec<SegmentLog> = Vec::new();
        let mut cur_seg = SegmentLog::default();
        if two_level {
            cycle_digests.reserve(nominal as usize + 2);
            cycle_digests.push(self.redmule.digest64());
            segments.push(SegmentLog::default());
        }
        // Checkpoint 0: after programming + start, before the first step —
        // the restore point for faults striking at cycle 1.
        checkpoints.push(snap(&self.redmule, &self.tcdm));
        loop {
            let mark = self.tcdm.dirty_log_len();
            self.redmule.step(&mut self.tcdm, &mut ctx);
            if two_level {
                cycle_digests.push(self.redmule.digest64());
                let cycle = self.redmule.cycle;
                // Capture this step's writes with their post-step stored
                // codewords. Several writes to one word within a step all
                // record the final value — harmless, the probe's
                // latest-write-wins lookup keeps the last entry anyway.
                for &idx in self.tcdm.dirty_log_since(mark) {
                    cur_seg
                        .log
                        .push((cycle, idx, self.tcdm.raw_codeword_flat(idx)));
                }
            }
            match self.redmule.state() {
                RunState::Done => break,
                RunState::Aborted => {
                    return Err(Error::Sim(
                        "fault-free reference run aborted — broken build".into(),
                    ));
                }
                _ => {}
            }
            if self.redmule.cycle > budget {
                return Err(Error::Sim(
                    "fault-free reference run exceeded the cycle budget".into(),
                ));
            }
            if self.redmule.cycle % interval == 0 {
                checkpoints.push(snap(&self.redmule, &self.tcdm));
                if two_level {
                    cur_seg.finalize();
                    segments.push(std::mem::take(&mut cur_seg));
                }
            }
        }
        let cycles = self.redmule.cycle;
        let abft = if self.protection().has_abft_checksums() {
            let mm = self.abft_check(layout, None);
            config_cycles += (layout.m + layout.k) as u64;
            if !mm.is_clean() {
                // Tolerance at/below the rounding bound: the clean run
                // itself retries, so a converged state has no clean tail
                // to inherit. Soft-decline the trace.
                return Ok(None);
            }
            Some(AbftRunInfo::default())
        } else {
            None
        };
        let z = self.final_z(layout);
        let two_level = two_level.then(|| {
            cur_seg.finalize();
            TwoLevelRef {
                cycle_digests,
                segments,
                tail: cur_seg,
            }
        });
        Ok(Some(RefTrace {
            interval,
            cycles,
            program_cycles,
            config_cycles,
            z,
            abft,
            checkpoints,
            two_level,
        }))
    }

    /// Hosted execution with an optional fault plan (the campaign's unit
    /// of work). Implements the §3.3 recovery flow.
    pub fn run_gemm_with_fault(
        &mut self,
        p: &GemmProblem,
        mode: ExecMode,
        plan: Option<FaultPlan>,
    ) -> Result<RunReport> {
        match plan {
            Some(pl) => self.run_gemm_with_faults(p, mode, std::slice::from_ref(&pl)),
            None => self.run_gemm_with_faults(p, mode, &[]),
        }
    }

    /// Hosted execution with `plans.len()` planned faults (empty = clean
    /// run). The sweep engine's multi-fault unit of work.
    pub fn run_gemm_with_faults(
        &mut self,
        p: &GemmProblem,
        mode: ExecMode,
        plans: &[FaultPlan],
    ) -> Result<RunReport> {
        if p.spec.m == 0 || p.spec.n == 0 || p.spec.k == 0 {
            return Err(Error::Config("degenerate GEMM".into()));
        }
        // Power-on-equivalent accelerator state: campaign runs are
        // independent experiments and cycle numbering must restart at 0
        // (fault plans are expressed in absolute cycles).
        self.redmule.reset();
        let layout = self.stage(p)?;
        self.run_staged_with_faults(&layout, mode, plans)
    }

    /// Single-plan convenience wrapper around
    /// [`System::run_staged_with_faults`].
    pub fn run_staged_with_fault(
        &mut self,
        layout: &TaskLayout,
        mode: ExecMode,
        plan: Option<FaultPlan>,
    ) -> Result<RunReport> {
        match plan {
            Some(pl) => self.run_staged_with_faults(layout, mode, std::slice::from_ref(&pl)),
            None => self.run_staged_with_faults(layout, mode, &[]),
        }
    }

    /// Like [`System::run_gemm_with_faults`] but assuming the task is
    /// already staged at `layout` (and the accelerator freshly reset).
    /// The campaign uses this with a snapshot/restore of the TCDM image:
    /// staging through the DMA + ECC encoders costs more than the run
    /// itself on small workloads, and the staged bits are identical for
    /// every injection (see EXPERIMENTS.md §Perf).
    pub fn run_staged_with_faults(
        &mut self,
        layout: &TaskLayout,
        mode: ExecMode,
        plans: &[FaultPlan],
    ) -> Result<RunReport> {
        let mut ctx = FaultCtx::clean();
        self.run_staged_with_faults_scratch(layout, mode, plans, &mut ctx)
    }

    /// [`System::run_staged_with_faults`] with a caller-owned reusable
    /// fault context: the campaign hot loop re-arms one worker-local
    /// `FaultCtx` per injection (`reset_with_plans`) instead of
    /// allocating a plan `Vec` per run. Behavior is identical.
    pub fn run_staged_with_faults_scratch(
        &mut self,
        layout: &TaskLayout,
        mode: ExecMode,
        plans: &[FaultPlan],
        ctx: &mut FaultCtx,
    ) -> Result<RunReport> {
        if plans.len() > crate::fault::MAX_PLANS_PER_RUN {
            return Err(Error::Config(format!(
                "at most {} faults per run ({} planned)",
                crate::fault::MAX_PLANS_PER_RUN,
                plans.len()
            )));
        }
        let config_cycles = self.program(layout, mode);
        ctx.reset_with_plans(plans);
        self.host_loop(*layout, mode, ctx, config_cycles, None)
    }

    /// Fast-forwarded hosted execution — the checkpointed counterpart of
    /// [`System::run_staged_with_faults`], producing a **bit-identical
    /// [`RunReport`]** at a fraction of the simulated cycles:
    ///
    /// 1. restore the reference checkpoint just before the earliest
    ///    planned fault (TCDM copy-on-write from the pristine image plus
    ///    the checkpoint's delta; full accelerator state snapshot) — the
    ///    skipped prefix is bit-identical to what the direct path would
    ///    have stepped, because no plan can fire before its cycle;
    /// 2. step normally from there (faults land exactly as in the direct
    ///    path, cycle numbering is absolute);
    /// 3. once every plan's cycle is behind, compare the rolling state
    ///    digest against the reference at each checkpoint boundary — on a
    ///    match the fault was masked or absorbed and the recorded clean
    ///    tail substitutes for the rest of the simulation.
    ///
    /// The caller owns consistency: `trace` and `pristine` must have been
    /// built from the *same* staged problem/layout/mode on the same
    /// build (the campaign engine guarantees this; `tests/fastforward.rs`
    /// pins the equivalence end to end).
    pub fn run_staged_with_faults_ff(
        &mut self,
        layout: &TaskLayout,
        mode: ExecMode,
        plans: &[FaultPlan],
        trace: &RefTrace,
        pristine: &Tcdm,
    ) -> Result<RunReport> {
        let mut ctx = FaultCtx::clean();
        self.run_staged_with_faults_ff_scratch(layout, mode, plans, trace, pristine, &mut ctx)
    }

    /// [`System::run_staged_with_faults_ff`] with a caller-owned
    /// reusable fault context (see
    /// [`System::run_staged_with_faults_scratch`]). Behavior is
    /// identical; the steady-state injection performs no heap
    /// allocation in the restore/plan/digest machinery.
    pub fn run_staged_with_faults_ff_scratch(
        &mut self,
        layout: &TaskLayout,
        mode: ExecMode,
        plans: &[FaultPlan],
        trace: &RefTrace,
        pristine: &Tcdm,
        ctx: &mut FaultCtx,
    ) -> Result<RunReport> {
        if plans.len() > crate::fault::MAX_PLANS_PER_RUN {
            return Err(Error::Config(format!(
                "at most {} faults per run ({} planned)",
                crate::fault::MAX_PLANS_PER_RUN,
                plans.len()
            )));
        }
        let Some(first) = first_fault_cycle(plans) else {
            // Nothing will ever fire: the recorded reference run IS the
            // result, no simulation needed at all.
            return Ok(trace.clean_report());
        };
        if !self.tcdm.dirty_tracking_enabled() {
            // restore_from would silently undo nothing.
            return Err(Error::Config(
                "fast-forward execution needs TCDM dirty tracking enabled".into(),
            ));
        }
        let cp = trace.checkpoint_before(first);
        self.tcdm.restore_from(pristine);
        self.tcdm.apply_delta(&cp.tcdm_delta);
        self.redmule.restore_from(&cp.redmule);
        ctx.reset_with_plans(plans);
        let resume = FfResume {
            trace,
            pristine,
            last_plan_cycle: last_fault_cycle(plans).unwrap_or(0),
            regfile_untouched: plans
                .iter()
                .all(|p| p.site.module() != crate::fault::Module::RegFile),
            probe: ResumeProbe::FullDigest,
        };
        // The checkpoint already contains the programmed register file, so
        // the initial `program()` is skipped and its recorded cost carried
        // over instead.
        self.host_loop(*layout, mode, ctx, trace.program_cycles, Some(resume))
    }

    /// Two-level hosted execution — the executor's functional fast path
    /// with a cycle-accurate fault *window*:
    ///
    /// 1. **functional level**: the fault-free prefix is not stepped at
    ///    all — the nearest reference checkpoint before the earliest
    ///    planned fault is restored (same as fast-forward);
    /// 2. **cycle-accurate window**: the window sized by
    ///    [`crate::fault::plan_window`] plus pipeline settling is stepped
    ///    through the full accelerator model — faults land exactly as in
    ///    the direct path;
    /// 3. **re-convergence**: past the window, mid-segment probes (every
    ///    `exec::EARLY_PROBE_STRIDE` cycles, plus every checkpoint
    ///    boundary) prove bit-identity with the reference from the
    ///    per-cycle digests + segment write logs, and the recorded clean
    ///    tail substitutes for the rest.
    ///
    /// The [`RunReport`] is **bit-identical** to the direct and the
    /// fast-forward engines (`tests/fastforward.rs`,
    /// `tests/shared_trace.rs`, `tests/twolevel.rs`); a trace without
    /// two-level instrumentation degrades gracefully to checkpoint-
    /// boundary probing (= fast-forward).
    pub fn run_staged_with_faults_tl(
        &mut self,
        layout: &TaskLayout,
        mode: ExecMode,
        plans: &[FaultPlan],
        trace: &RefTrace,
        pristine: &Tcdm,
    ) -> Result<RunReport> {
        let mut ctx = FaultCtx::clean();
        self.run_staged_with_faults_tl_scratch(layout, mode, plans, trace, pristine, &mut ctx)
    }

    /// [`System::run_staged_with_faults_tl`] with a caller-owned reusable
    /// fault context (see [`System::run_staged_with_faults_scratch`]).
    pub fn run_staged_with_faults_tl_scratch(
        &mut self,
        layout: &TaskLayout,
        mode: ExecMode,
        plans: &[FaultPlan],
        trace: &RefTrace,
        pristine: &Tcdm,
        ctx: &mut FaultCtx,
    ) -> Result<RunReport> {
        self.run_staged_with_faults_tl_cached(layout, mode, plans, trace, pristine, ctx, &mut None)
    }

    /// [`System::run_staged_with_faults_tl_scratch`] with a caller-owned
    /// checkpoint-restore cache coalescing adjacent fault windows: when
    /// the previous call on this cache resumed from the same reference
    /// checkpoint, the TCDM is rewound to the checkpoint image by
    /// undoing only that window's writes past the recorded log watermark
    /// ([`Tcdm::undo_to_watermark`]) instead of a full pristine restore
    /// plus delta replay. Contents, write log and therefore the
    /// [`RunReport`] stay bit-identical (`tests/twolevel.rs` A/B-pins
    /// it) because no mid-run path shrinks the log below the watermark —
    /// every store, scrub writebacks included, appends to it.
    ///
    /// Contract on `restore_cache`: reuse it only across consecutive
    /// calls with the same `trace` and `pristine` on this `System`, with
    /// no intervening TCDM mutation outside these calls; pass a fresh
    /// `&mut None` otherwise (which is exactly the uncached engine).
    #[allow(clippy::too_many_arguments)]
    pub fn run_staged_with_faults_tl_cached(
        &mut self,
        layout: &TaskLayout,
        mode: ExecMode,
        plans: &[FaultPlan],
        trace: &RefTrace,
        pristine: &Tcdm,
        ctx: &mut FaultCtx,
        restore_cache: &mut Option<(usize, usize)>,
    ) -> Result<RunReport> {
        if plans.len() > crate::fault::MAX_PLANS_PER_RUN {
            return Err(Error::Config(format!(
                "at most {} faults per run ({} planned)",
                crate::fault::MAX_PLANS_PER_RUN,
                plans.len()
            )));
        }
        let Some(first) = first_fault_cycle(plans) else {
            return Ok(trace.clean_report());
        };
        if !self.tcdm.dirty_tracking_enabled() {
            return Err(Error::Config(
                "two-level execution needs TCDM dirty tracking enabled".into(),
            ));
        }
        let base_idx = trace.checkpoint_index_before(first);
        let cp = &trace.checkpoints[base_idx];
        match *restore_cache {
            // Coalesced: the log prefix `[0, mark)` is the previous
            // restore's delta replay, still valid — undo only the
            // writes past it.
            Some((idx, mark)) if idx == base_idx && self.tcdm.dirty_log_len() >= mark => {
                self.tcdm.undo_to_watermark(pristine, &cp.tcdm_delta, mark);
            }
            _ => {
                self.tcdm.restore_from(pristine);
                self.tcdm.apply_delta(&cp.tcdm_delta);
                *restore_cache = Some((base_idx, self.tcdm.dirty_log_len()));
            }
        }
        self.redmule.restore_from(&cp.redmule);
        ctx.reset_with_plans(plans);
        let last = last_fault_cycle(plans).unwrap_or(0);
        let probe = if trace.two_level.is_some() {
            // Watermark after the delta: delta words already carry the
            // checkpoint's (= reference's) content, so only writes past
            // this point can diverge from the reference.
            let window_mark = self.tcdm.dirty_log_len();
            let settle = exec::window_settle(self.redmule.dims().d as u64);
            let window_end = crate::fault::plan_window(plans, settle, trace.cycles)
                .map_or(last, |(_, end)| end);
            ResumeProbe::Window {
                base_idx,
                window_mark,
                window_end,
            }
        } else {
            ResumeProbe::FullDigest
        };
        let resume = FfResume {
            trace,
            pristine,
            last_plan_cycle: last,
            regfile_untouched: plans
                .iter()
                .all(|p| p.site.module() != crate::fault::Module::RegFile),
            probe,
        };
        self.host_loop(*layout, mode, ctx, trace.program_cycles, Some(resume))
    }

    /// The §3.3 host recovery loop, shared by the direct and the
    /// fast-forwarded engines. With `resume` set, the first attempt
    /// continues from a restored mid-task checkpoint (no `start()`) and
    /// probes for convergence against the reference trace; every retry
    /// attempt is identical in both engines.
    fn host_loop(
        &mut self,
        layout: TaskLayout,
        mode: ExecMode,
        ctx: &mut FaultCtx,
        mut config_cycles: u64,
        ff_resume: Option<FfResume<'_>>,
    ) -> Result<RunReport> {
        let abft = self.protection().has_abft_checksums();
        let nominal = self.redmule.nominal_cycles().max(1);
        let budget = nominal * TIMEOUT_FACTOR;

        let mut total_cycles = 0u64;
        let mut retries = 0u32;
        let mut causes = 0u32;
        let mut irq_seen_any = false;
        let mut abft_info = AbftRunInfo::default();
        // Rows of the current ABFT band re-execution (None = full task).
        let mut band: Option<(u32, u32)> = None;

        let mut first_attempt = true;
        loop {
            use exec::Backend;
            let resumed = if first_attempt { ff_resume.as_ref() } else { None };
            // Two-level executor dispatch: the first attempt runs on the
            // functional backend when a reference trace is available
            // (fast-forward restore + convergence probes), the
            // cycle-accurate backend otherwise. Retries always step the
            // full model — both engines simulate them identically.
            let exit = match resumed {
                Some(ff) => exec::Functional { resume: ff }.attempt(self, ctx, budget),
                None => exec::CycleAccurate.attempt(self, ctx, budget),
            };
            if exit.converged {
                // The probed state matched the reference at this cycle:
                // every remaining cycle would replay the fault-free tail
                // bit for bit, so substitute the recorded clean outcome.
                // Fault bookkeeping (applied counts, observed IRQ
                // transients) is taken from the simulated part.
                let ff = resumed.expect("only the functional backend converges");
                return Ok(RunReport {
                    outcome: HostOutcome::Completed,
                    cycles: ff.trace.cycles,
                    config_cycles: ff.trace.config_cycles,
                    retries: 0,
                    fault_causes: 0,
                    irq_seen: exit.irq_seen,
                    faults_applied: ctx.applied_faults(),
                    abft: ff.trace.abft,
                    z: ff.trace.z.clone(),
                });
            }
            let (aborted, cycles, irq_seen) = (exit.aborted, exit.cycles, exit.irq_seen);
            first_attempt = false;
            total_cycles += cycles;
            irq_seen_any |= irq_seen;

            if self.redmule.state() == RunState::Done {
                if abft {
                    // Online in-place correction (`AbftOnline` +
                    // `InPlaceCorrect`): consult the exact store residuals
                    // first. A single-element verdict is repaired by one
                    // Z read-modify-write — the carried-checksum check
                    // below then validates the *repaired* image, so a
                    // confused locate (tap-net transient, residual SEU)
                    // degrades to an ordinary detection, never to silent
                    // corruption. Non-single verdicts are folded into the
                    // mismatch set so the recompute fallback below covers
                    // them.
                    let mut residual_rows: Vec<usize> = Vec::new();
                    let mut residual_cols: Vec<usize> = Vec::new();
                    if self.recovery == RecoveryPolicy::InPlaceCorrect
                        && self.redmule.abft.online()
                    {
                        let verdict = analyze_residuals(
                            self.redmule.abft.res_rows(),
                            self.redmule.abft.res_cols(),
                        );
                        let mut corrected = false;
                        if let ResidualVerdict::Single { row, col, delta_bits, .. } = verdict {
                            // Residual coordinates are band-relative after
                            // a band recompute; map back to the full task.
                            let abs_row = band.map_or(row, |(r0, _)| r0 as usize + row);
                            let k_aug = layout.k as usize;
                            if abs_row < layout.m as usize && col < k_aug {
                                let addr =
                                    layout.z_addr + ((abs_row * k_aug + col) * 2) as u32;
                                let stored = self.tcdm.read_fp16(addr).0;
                                if let Some(fixed) = correct_from_residual(stored, delta_bits)
                                {
                                    self.tcdm.write_fp16(addr, fixed);
                                    causes |= cause::ABFT_CHECKSUM;
                                    abft_info.detections += 1;
                                    abft_info.corrections += 1;
                                    config_cycles += ABFT_CORRECT_CYCLES;
                                    self.redmule.abft.adjust_observation(
                                        row, col, stored, fixed,
                                    );
                                    self.redmule.abft.clear_residuals();
                                    corrected = true;
                                }
                            }
                        }
                        if !corrected && verdict != ResidualVerdict::Clean {
                            // Multi-error or uncorrectable pattern: every
                            // flagged row/column joins the mismatch set.
                            let (rfx, rbits) = self.redmule.abft.res_rows();
                            for (i, (&fx, &b)) in rfx.iter().zip(rbits).enumerate() {
                                if fx != 0 || b != 0 {
                                    let abs_row =
                                        band.map_or(i, |(r0, _)| r0 as usize + i);
                                    residual_rows.push(abs_row);
                                }
                            }
                            let (cfx, cbits) = self.redmule.abft.res_cols();
                            for (j, (&fx, &b)) in cfx.iter().zip(cbits).enumerate() {
                                if fx != 0 || b != 0 {
                                    residual_cols.push(j);
                                }
                            }
                        }
                    }
                    // Writeback verification: observed row/column sums
                    // from the checksum unit vs. the carried checksums.
                    let mut mm = self.abft_check(&layout, band);
                    config_cycles += (layout.m + layout.k) as u64;
                    if !residual_rows.is_empty() || !residual_cols.is_empty() {
                        mm.rows.extend(residual_rows);
                        mm.rows.sort_unstable();
                        mm.rows.dedup();
                        mm.cols.extend(residual_cols);
                        mm.cols.sort_unstable();
                        mm.cols.dedup();
                    }
                    if !mm.is_clean() {
                        causes |= cause::ABFT_CHECKSUM;
                        abft_info.detections += 1;
                        if retries >= MAX_RETRIES {
                            return Ok(RunReport {
                                outcome: HostOutcome::Abandoned,
                                cycles: total_cycles,
                                config_cycles,
                                retries,
                                fault_causes: causes,
                                irq_seen: irq_seen_any,
                                faults_applied: ctx.applied_faults(),
                                abft: Some(abft_info),
                                z: self.final_z(&layout),
                            });
                        }
                        retries += 1;
                        if self.recovery != RecoveryPolicy::FullRestart && !mm.rows.is_empty() {
                            // Selective recovery: recompute only the row
                            // band covering the located rows. Inputs are
                            // pristine in TCDM; rows are contiguous in
                            // row-major layout, so the band is itself a
                            // smaller contiguous GEMM.
                            let r0 = mm.rows[0] as u32;
                            let r1 = *mm.rows.last().unwrap() as u32;
                            band = Some((r0, r1));
                            abft_info.band_recomputes += 1;
                            config_cycles += self.program_abft_band(&layout, mode, r0, r1);
                        } else {
                            // Column-only mismatch (corruption consistent
                            // along rows, e.g. an upset operand feeding a
                            // whole row) cannot be localized: recompute
                            // the full task.
                            band = None;
                            abft_info.full_restarts += 1;
                            config_cycles += self.program(&layout, mode);
                        }
                        continue;
                    }
                }
                let z = self.final_z(&layout);
                // An in-place correction is a recovery action too: the
                // result only matches golden *because* the host repaired
                // it, so it classifies with the retried runs.
                let outcome = if retries > 0 || abft_info.corrections > 0 {
                    HostOutcome::CompletedAfterRetry
                } else {
                    HostOutcome::Completed
                };
                return Ok(RunReport {
                    outcome,
                    cycles: total_cycles,
                    config_cycles,
                    retries,
                    fault_causes: causes,
                    irq_seen: irq_seen_any,
                    faults_applied: ctx.applied_faults(),
                    abft: abft.then_some(abft_info),
                    z,
                });
            }

            if aborted && irq_seen {
                // Interrupt service: read the progress register, then
                // read + clear the status registers.
                let progress = self.redmule.fault_unit.progress_tile();
                let (status, _count) = self.redmule.fault_unit.read_clear();
                causes |= status;
                let retry_allowed = mode == ExecMode::FaultTolerant
                    || self.redmule.protection.has_control_protection()
                    || self.redmule.protection.has_per_ce_checkers();
                if !retry_allowed || retries >= MAX_RETRIES {
                    return Ok(RunReport {
                        outcome: HostOutcome::Abandoned,
                        cycles: total_cycles,
                        config_cycles,
                        retries,
                        fault_causes: causes,
                        irq_seen: irq_seen_any,
                        faults_applied: ctx.applied_faults(),
                        abft: abft.then_some(abft_info),
                        z: self.final_z(&layout),
                    });
                }
                retries += 1;
                // Re-program (repairs any configuration upset — the host
                // rewrites values *and* parity) and re-execute. Cycle
                // numbering keeps running across attempts, so a transient
                // plan that already fired (or missed) cannot strike again;
                // in a multi-fault run only the plans whose cycles are
                // still ahead stay armed — which is exactly how the sweep
                // exercises faults *during* the recomputation phase the
                // paper's single-fault campaign assumes clean.
                let resume = match self.recovery {
                    RecoveryPolicy::FullRestart => None,
                    RecoveryPolicy::TileLevel | RecoveryPolicy::InPlaceCorrect => Some(progress),
                };
                config_cycles += self.program_with_resume(&layout, mode, resume);
                // Retry shortcut (fast-forward engine only): a FullRestart
                // retry is bit-for-bit the reference run again when (1) no
                // plan can fire any more, (2) no plan ever struck the
                // register file — the only state a re-program does not
                // fully rewrite; the interrupt service + `start()` reset
                // everything else — and (3) the staged inputs in TCDM are
                // untouched (the aborted attempt wrote nothing outside the
                // Z region, which a full recompute rewrites entirely). The
                // recorded clean outcome then substitutes for stepping the
                // whole re-execution. TileLevel resumes depend on the
                // partially-committed Z content, and ABFT builds run a
                // writeback verification after the retry (extra host
                // cycles + accumulator-dependent behavior), so both
                // always simulate.
                if let Some(ff) = &ff_resume {
                    if self.recovery == RecoveryPolicy::FullRestart
                        && !abft
                        && ff.regfile_untouched
                        && self.redmule.cycle >= ff.last_plan_cycle
                    {
                        // Delta indices are bank-major flats; map each
                        // back to its linear word address before testing
                        // it against the Z region's word span.
                        let z_first_word = layout.z_addr / 4;
                        let z_end_word = (layout.z_addr + 2 * layout.m * layout.k).div_ceil(4);
                        let inputs_pristine =
                            self.tcdm.dirty_delta(ff.pristine).iter().all(|&(idx, _)| {
                                let w = self.tcdm.linear_word_of(idx);
                                w >= z_first_word && w < z_end_word
                            });
                        if inputs_pristine {
                            return Ok(RunReport {
                                outcome: HostOutcome::CompletedAfterRetry,
                                cycles: total_cycles + ff.trace.cycles,
                                config_cycles,
                                retries,
                                fault_causes: causes,
                                irq_seen: irq_seen_any,
                                faults_applied: ctx.applied_faults(),
                                abft: abft.then_some(abft_info),
                                z: ff.trace.z.clone(),
                            });
                        }
                    }
                }
                continue;
            }

            // Aborted but the host never saw the IRQ (only possible under
            // injected faults on the interrupt path), or budget exhausted:
            // the workload hangs until the watchdog fires.
            return Ok(RunReport {
                outcome: HostOutcome::TimedOut,
                cycles: total_cycles,
                config_cycles,
                retries,
                fault_causes: causes,
                irq_seen: irq_seen_any,
                faults_applied: ctx.applied_faults(),
                abft: abft.then_some(abft_info),
                z: self.final_z(&layout),
            });
        }
    }

    /// Fault-free hosted execution.
    pub fn run_gemm(&mut self, p: &GemmProblem, mode: ExecMode) -> Result<RunReport> {
        self.run_gemm_with_fault(p, mode, None)
    }

    /// Read the Z region back from TCDM.
    pub fn read_z(&mut self, layout: &TaskLayout) -> Mat {
        let n = (layout.m * layout.k) as usize;
        let data = self.tcdm.read_fp16_slice(layout.z_addr, n);
        Mat {
            rows: layout.m as usize,
            cols: layout.k as usize,
            data,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::golden::GemmSpec;

    fn run(protection: Protection, mode: ExecMode, spec: GemmSpec, seed: u64) -> (RunReport, Mat) {
        let mut sys = System::new(RedMuleConfig::paper(), protection);
        let p = GemmProblem::random(&spec, seed);
        let golden = p.golden_z();
        let r = sys.run_gemm(&p, mode).unwrap();
        (r, golden)
    }

    #[test]
    fn baseline_performance_mode_is_bit_exact() {
        let (r, golden) = run(
            Protection::Baseline,
            ExecMode::Performance,
            GemmSpec::paper_workload(),
            42,
        );
        assert_eq!(r.outcome, HostOutcome::Completed);
        assert!(r.z_matches(&golden), "simulator must equal golden");
        assert_eq!(r.retries, 0);
        assert!(!r.irq_seen);
    }

    #[test]
    fn full_ft_mode_is_bit_exact() {
        let (r, golden) = run(
            Protection::Full,
            ExecMode::FaultTolerant,
            GemmSpec::paper_workload(),
            43,
        );
        assert_eq!(r.outcome, HostOutcome::Completed);
        assert!(r.z_matches(&golden));
    }

    #[test]
    fn data_ft_mode_is_bit_exact() {
        let (r, golden) = run(
            Protection::Data,
            ExecMode::FaultTolerant,
            GemmSpec::paper_workload(),
            44,
        );
        assert_eq!(r.outcome, HostOutcome::Completed);
        assert!(r.z_matches(&golden));
    }

    #[test]
    fn odd_shapes_are_handled() {
        for (m, n, k) in [(1, 1, 1), (5, 7, 3), (13, 17, 19), (12, 16, 16), (24, 16, 25)] {
            for (prot, mode) in [
                (Protection::Baseline, ExecMode::Performance),
                (Protection::Full, ExecMode::FaultTolerant),
                (Protection::Full, ExecMode::Performance),
            ] {
                let (r, golden) = run(prot, mode, GemmSpec::new(m, n, k), 7 + m as u64);
                assert_eq!(r.outcome, HostOutcome::Completed, "({m},{n},{k}) {prot:?} {mode:?}");
                assert!(
                    r.z_matches(&golden),
                    "({m},{n},{k}) {prot:?} {mode:?} mismatch"
                );
            }
        }
    }

    #[test]
    fn abft_build_is_bit_exact_and_strips_checksums() {
        let (r, golden) = run(
            Protection::Abft,
            ExecMode::Performance,
            GemmSpec::paper_workload(),
            45,
        );
        assert_eq!(r.outcome, HostOutcome::Completed);
        assert_eq!((r.z.rows, r.z.cols), (12, 16), "checksums must be stripped");
        assert!(r.z_matches(&golden), "ABFT data region must equal golden");
        assert_eq!(r.retries, 0, "fault-free ABFT run must not retry");
        assert_eq!(r.abft, Some(AbftRunInfo::default()));
        assert!(!r.irq_seen);
    }

    #[test]
    fn abft_runs_at_performance_speed() {
        // No row duplication: the ABFT run costs ~the baseline run of the
        // augmented (m+1, n, k+1) workload, far below the FT-mode 2x.
        let spec = GemmSpec::new(12, 64, 48);
        let (abft, _) = run(Protection::Abft, ExecMode::Performance, spec, 5);
        let (ft, _) = run(Protection::Full, ExecMode::FaultTolerant, spec, 5);
        assert!(
            (abft.cycles as f64) < 0.75 * ft.cycles as f64,
            "abft {} vs ft {} cycles",
            abft.cycles,
            ft.cycles
        );
    }

    #[test]
    fn ft_mode_costs_about_2x_cycles() {
        let spec = GemmSpec::new(12, 64, 48);
        let (perf, _) = run(Protection::Full, ExecMode::Performance, spec, 5);
        let (ft, _) = run(Protection::Full, ExecMode::FaultTolerant, spec, 5);
        let ratio = ft.cycles as f64 / perf.cycles as f64;
        assert!(
            (1.6..=2.4).contains(&ratio),
            "FT/perf cycle ratio {ratio:.2} should be ≈2"
        );
    }

    #[test]
    fn config_parity_cost_only_on_protected_builds() {
        let spec = GemmSpec::paper_workload();
        let (full, _) = run(Protection::Full, ExecMode::FaultTolerant, spec, 9);
        let (base, _) = run(Protection::Baseline, ExecMode::Performance, spec, 9);
        assert_eq!(full.config_cycles, CONFIG_PARITY_CYCLES);
        assert!(base.config_cycles < 20);
    }

    #[test]
    fn restore_from_matches_a_freshly_staged_system() {
        // A long-lived scratch System (the sweep scheduler's worker
        // arena) that reconfigures to a cell's build and adopts its
        // pristine image must run bit-identically to a fresh System
        // that staged the workload itself.
        let cfg = RedMuleConfig::paper();
        let p = GemmProblem::random(&GemmSpec::new(6, 8, 8), 77);
        let mut fresh = System::new(cfg, Protection::Full);
        fresh.redmule.reset();
        let layout = fresh.stage(&p).unwrap();
        let pristine = fresh.tcdm.clone();
        fresh.tcdm.enable_dirty_tracking();
        let a = fresh
            .run_staged_with_faults(&layout, ExecMode::FaultTolerant, &[])
            .unwrap();
        let mut scratch = System::new(RedMuleConfig::new(8, 2, 2), Protection::Baseline);
        scratch.reconfigure(cfg, Protection::Full);
        scratch.restore_from(&pristine);
        let b = scratch
            .run_staged_with_faults(&layout, ExecMode::FaultTolerant, &[])
            .unwrap();
        assert_eq!(a.outcome, b.outcome);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.z.bits(), b.z.bits());
        // Re-adopting after a completed run restores a clean slate.
        scratch.restore_from(&pristine);
        let c = scratch
            .run_staged_with_faults(&layout, ExecMode::FaultTolerant, &[])
            .unwrap();
        assert_eq!(a.cycles, c.cycles);
        assert_eq!(a.z.bits(), c.z.bits());
    }

    #[test]
    fn ft_mode_on_baseline_build_silently_degrades_to_performance() {
        // Requesting FT mode without data-protection hardware cannot
        // duplicate rows; the accelerator runs unprotected.
        let (r, golden) = run(
            Protection::Baseline,
            ExecMode::FaultTolerant,
            GemmSpec::paper_workload(),
            11,
        );
        assert_eq!(r.outcome, HostOutcome::Completed);
        assert!(r.z_matches(&golden));
    }
}
