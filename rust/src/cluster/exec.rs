//! The two-level executor: one stepping-backend abstraction over the
//! host recovery loop.
//!
//! [`super::System::host_loop`] owns the §3.3 host protocol (interrupt
//! service, re-programming, ABFT verification, retry budget) and is
//! backend-agnostic: every *attempt* — the span from (re)start to Done,
//! abort, timeout or re-convergence — runs on a [`Backend`].
//!
//! * [`CycleAccurate`] steps the full accelerator model from `start()`.
//!   The direct engine uses it for every attempt; the fast-forward and
//!   two-level engines use it for retries (recovery behavior depends on
//!   partially-committed state, so retries always simulate).
//! * [`Functional`] continues a restored mid-task checkpoint and probes
//!   for re-convergence with the recorded reference, advancing the run
//!   to its known clean conclusion the moment the probe proves
//!   bit-identity. With a [`super::TwoLevelRef`]-instrumented trace the
//!   probe works mid-segment (accelerator digest + closed write-set
//!   comparison); otherwise it degrades to full-state digests at
//!   checkpoint boundaries (the PR-3 fast-forward engine).
//!
//! The fault window — the span the two-level engine *must* step
//! cycle-accurately — is the planned-fault hull from
//! [`crate::fault::plan_window`] widened by [`window_settle`]: after the
//! last possible strike, in-flight corruption can keep propagating for
//! one pipeline drain plus the two-cycle IRQ handshake before the state
//! either re-converges or visibly diverges. Probe *timing* is a pure
//! performance knob: a probe only ever substitutes the clean tail after
//! proving bit-identity, so reports are byte-identical no matter when
//! probes fire (pinned across the engine matrix by `tests/`).

use super::{FfResume, System};
use crate::fault::FaultCtx;

/// Which execution engine a mesh tile runs its clean shard attempts on
/// — the same three engines the campaign matrix exercises, selectable
/// per mesh. `Direct` steps the cycle-accurate model; `FastForward` and
/// `TwoLevel` use the functional level (bit-identical to the golden
/// model on clean runs by the crate's clean-run contract) priced with
/// the closed-form [`crate::perf::PhaseSchedule`]. Tile results are
/// byte-identical across all three, which `tests/mesh.rs` pins.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TileEngine {
    Direct,
    FastForward,
    TwoLevel,
}

impl TileEngine {
    pub fn name(self) -> &'static str {
        match self {
            TileEngine::Direct => "direct",
            TileEngine::FastForward => "fast-forward",
            TileEngine::TwoLevel => "two-level",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "direct" => TileEngine::Direct,
            "fast-forward" | "ff" => TileEngine::FastForward,
            "two-level" | "tl" => TileEngine::TwoLevel,
            _ => return None,
        })
    }

    pub const ALL: [TileEngine; 3] =
        [TileEngine::Direct, TileEngine::FastForward, TileEngine::TwoLevel];
}

/// Mid-segment convergence probe spacing of the two-level engine, in
/// cycles. Small enough that a settled run is caught within a few cycles
/// (instead of up to a checkpoint interval later), large enough that the
/// accelerator-digest fast path stays a trivial fraction of stepping.
pub(crate) const EARLY_PROBE_STRIDE: u64 = 8;

/// Architectural settling margin appended to both sides of the planned
/// fault hull: one pipeline drain (`d` cycles) covers in-flight FMA
/// corruption, plus the two-cycle IRQ assertion window and a two-cycle
/// scheduler hand-off margin.
pub(crate) fn window_settle(pipeline_depth: u64) -> u64 {
    pipeline_depth + 4
}

/// How one execution attempt ended.
pub(crate) struct AttemptExit {
    /// The accelerator aborted (fault-status latch fired).
    pub aborted: bool,
    /// Accelerator cycles charged to this attempt.
    pub cycles: u64,
    /// The host observed the IRQ wire asserted at least once.
    pub irq_seen: bool,
    /// The functional backend proved bit-identity with the reference —
    /// the recorded clean tail substitutes for the remaining cycles.
    /// The cycle-accurate backend never converges (it has no reference).
    pub converged: bool,
}

/// One stepping backend of the two-level executor.
pub(crate) trait Backend {
    /// Run one attempt to Done, abort, budget exhaustion or (functional
    /// backend only) re-convergence.
    fn attempt(&mut self, sys: &mut System, ctx: &mut FaultCtx, budget: u64) -> AttemptExit;
}

/// The cycle-accurate backend: start and step the full model.
pub(crate) struct CycleAccurate;

impl Backend for CycleAccurate {
    fn attempt(&mut self, sys: &mut System, ctx: &mut FaultCtx, budget: u64) -> AttemptExit {
        let (aborted, cycles, irq_seen) = sys.execute_attempt(ctx, budget);
        AttemptExit {
            aborted,
            cycles,
            irq_seen,
            converged: false,
        }
    }
}

/// The functional backend: continue a restored checkpoint, probing for
/// re-convergence with the reference trace carried in `resume`.
pub(crate) struct Functional<'a, 'b> {
    pub resume: &'b FfResume<'a>,
}

impl Backend for Functional<'_, '_> {
    fn attempt(&mut self, sys: &mut System, ctx: &mut FaultCtx, budget: u64) -> AttemptExit {
        let (aborted, cycles, irq_seen, converged) =
            sys.execute_resumed_attempt(ctx, budget, self.resume);
        AttemptExit {
            aborted,
            cycles,
            irq_seen,
            converged,
        }
    }
}
