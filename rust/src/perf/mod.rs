//! Performance model: the §4.1/§3.4 throughput story.
//!
//! RedMulE-FT's runtime configurability trades throughput for reliability:
//!
//! * **performance mode** — all `L` rows carry unique work;
//! * **fault-tolerant mode** — consecutive row pairs duplicate work, so
//!   the usable array is `L/2` rows: ≈2× the cycles for the same GEMM;
//! * configuration costs a one-time ≤120-cycle parity computation on the
//!   cores (§3.2), and a detected fault costs a full re-execution (§3.3,
//!   with tile-level recovery left as the paper's future work — see
//!   [`retry_expected_overhead`]).
//!
//! Analytic numbers come from the scheduler's closed-form cycle count;
//! measured numbers from stepping the simulator. The `perf_modes` bench
//! prints both and their agreement.

use crate::cluster::{System, CONFIG_PARITY_CYCLES};
use crate::golden::{GemmProblem, GemmSpec};
use crate::redmule::scheduler::{Dims, Scheduler};
use crate::redmule::{ExecMode, Protection, RedMuleConfig};
use crate::Result;

/// Frequency of the physical implementation (§4: 500 MHz in 12LP+, same
/// for all three builds — protection does not touch the critical path).
pub const FREQ_MHZ: f64 = 500.0;

/// Analytic fault-free cycle count for a workload in a mode.
pub fn analytic_cycles(cfg: RedMuleConfig, spec: GemmSpec, mode: ExecMode) -> u64 {
    let rows_per_tile = match mode {
        ExecMode::FaultTolerant => (cfg.l / 2).max(1) as u32,
        ExecMode::Performance => cfg.l as u32,
    };
    Scheduler::nominal_cycles(&Dims {
        m: spec.m as u32,
        n: spec.n as u32,
        k: spec.k as u32,
        rows_per_tile,
        d: cfg.d() as u32,
        h: cfg.h as u32,
    })
}

/// Peak and achieved throughput for a workload.
#[derive(Debug, Clone, Copy)]
pub struct Throughput {
    pub cycles: u64,
    pub macs: u64,
    /// MACs per cycle achieved.
    pub macs_per_cycle: f64,
    /// Utilization vs. the array's peak (L·H MACs/cycle).
    pub utilization: f64,
    /// GFLOPS at the published 500 MHz (2 FLOPs per MAC).
    pub gflops: f64,
}

pub fn throughput(cfg: RedMuleConfig, spec: GemmSpec, cycles: u64) -> Throughput {
    let macs = spec.macs();
    let mpc = macs as f64 / cycles.max(1) as f64;
    Throughput {
        cycles,
        macs,
        macs_per_cycle: mpc,
        utilization: mpc / cfg.macs_per_cycle() as f64,
        gflops: 2.0 * mpc * FREQ_MHZ / 1000.0,
    }
}

/// Measured cycles from the simulator (fault-free hosted run).
pub fn measured_cycles(
    cfg: RedMuleConfig,
    protection: Protection,
    spec: GemmSpec,
    mode: ExecMode,
) -> Result<u64> {
    let mut sys = System::new(cfg, protection);
    let p = GemmProblem::random(&spec, 0x9E37);
    let r = sys.run_gemm(&p, mode)?;
    Ok(r.cycles)
}

/// Expected per-workload cycle overhead of the retry mechanism given a
/// detection probability `p_retry` (from the campaign): a detected fault
/// aborts mid-flight (on average half the workload is lost) and triggers
/// reconfiguration plus a full re-execution.
pub fn retry_expected_overhead(base_cycles: u64, p_retry: f64) -> f64 {
    let c = base_cycles as f64;
    p_retry * (0.5 * c + CONFIG_PARITY_CYCLES as f64 + c)
}

/// One row of the mode-comparison report (the §4.1 performance claims).
#[derive(Debug, Clone)]
pub struct ModeReport {
    pub spec: GemmSpec,
    pub perf_cycles: u64,
    pub ft_cycles: u64,
    pub slowdown: f64,
    pub perf_util: f64,
    pub ft_util: f64,
}

pub fn mode_report(cfg: RedMuleConfig, protection: Protection, spec: GemmSpec) -> Result<ModeReport> {
    let perf = measured_cycles(cfg, protection, spec, ExecMode::Performance)?;
    let ft = measured_cycles(cfg, protection, spec, ExecMode::FaultTolerant)?;
    Ok(ModeReport {
        spec,
        perf_cycles: perf,
        ft_cycles: ft,
        slowdown: ft as f64 / perf as f64,
        perf_util: throughput(cfg, spec, perf).utilization,
        ft_util: throughput(cfg, spec, ft).utilization,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_matches_measured_for_paper_workload() {
        let cfg = RedMuleConfig::paper();
        let spec = GemmSpec::paper_workload();
        for (prot, mode) in [
            (Protection::Baseline, ExecMode::Performance),
            (Protection::Full, ExecMode::FaultTolerant),
            (Protection::Full, ExecMode::Performance),
        ] {
            let a = analytic_cycles(cfg, spec, if prot.has_data_protection() { mode } else { ExecMode::Performance });
            let m = measured_cycles(cfg, prot, spec, mode).unwrap();
            assert_eq!(a, m, "{prot:?}/{mode:?}");
        }
    }

    #[test]
    fn ft_slowdown_approaches_2x_for_large_workloads() {
        let cfg = RedMuleConfig::paper();
        let r = mode_report(cfg, Protection::Full, GemmSpec::new(48, 96, 96)).unwrap();
        assert!(
            (1.8..=2.2).contains(&r.slowdown),
            "slowdown {:.2} should be ≈2 (perf={}, ft={})",
            r.slowdown,
            r.perf_cycles,
            r.ft_cycles
        );
    }

    #[test]
    fn utilization_is_high_in_steady_state() {
        // Large-N workloads amortize load/drain/store: utilization should
        // approach 1 MAC/CE/cycle in performance mode.
        let cfg = RedMuleConfig::paper();
        let spec = GemmSpec::new(12, 256, 12);
        let t = throughput(cfg, spec, analytic_cycles(cfg, spec, ExecMode::Performance));
        assert!(t.utilization > 0.7, "utilization {:.2}", t.utilization);
    }

    #[test]
    fn retry_overhead_scales_with_probability() {
        let base = 1000;
        assert_eq!(retry_expected_overhead(base, 0.0), 0.0);
        let at_12pct = retry_expected_overhead(base, 0.12);
        // ~12 % of runs pay ~1.5× the workload plus reconfiguration.
        assert!((150.0..=220.0).contains(&at_12pct), "{at_12pct}");
    }

    #[test]
    fn gflops_at_peak_matches_array_size() {
        let cfg = RedMuleConfig::paper();
        // Hypothetical perfect utilization: L·H MACs/cycle at 500 MHz.
        let spec = GemmSpec::new(12, 4096, 12);
        let cycles = spec.macs() / cfg.macs_per_cycle() as u64;
        let t = throughput(cfg, spec, cycles);
        assert!((t.gflops - 48.0).abs() < 0.5, "peak ≈ 48 GFLOPS, got {:.1}", t.gflops);
    }
}
