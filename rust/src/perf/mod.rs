//! Performance model: the §4.1/§3.4 throughput story.
//!
//! RedMulE-FT's runtime configurability trades throughput for reliability:
//!
//! * **performance mode** — all `L` rows carry unique work;
//! * **fault-tolerant mode** — consecutive row pairs duplicate work, so
//!   the usable array is `L/2` rows: ≈2× the cycles for the same GEMM;
//! * configuration costs a one-time ≤120-cycle parity computation on the
//!   cores (§3.2), and a detected fault costs a full re-execution (§3.3,
//!   with tile-level recovery left as the paper's future work — see
//!   [`retry_expected_overhead`]).
//!
//! Analytic numbers come from the scheduler's closed-form cycle count;
//! measured numbers from stepping the simulator. The `perf_modes` bench
//! prints both and their agreement.

use crate::cluster::{System, ABFT_CORRECT_CYCLES, CONFIG_PARITY_CYCLES};
use crate::golden::{GemmProblem, GemmSpec};
use crate::redmule::scheduler::{Dims, Scheduler};
use crate::redmule::{ExecMode, Protection, RedMuleConfig};
use crate::Result;

/// Frequency of the physical implementation (§4: 500 MHz in 12LP+, same
/// for all three builds — protection does not touch the critical path).
pub const FREQ_MHZ: f64 = 500.0;

/// The scheduler dimensions a (config, spec, mode) triple resolves to —
/// the same mapping [`crate::redmule::RedMule::dims`] performs from the
/// latched register file (FT mode halves the usable rows).
pub fn dims_of(cfg: RedMuleConfig, spec: GemmSpec, mode: ExecMode) -> Dims {
    let rows_per_tile = match mode {
        ExecMode::FaultTolerant => (cfg.l / 2).max(1) as u32,
        ExecMode::Performance => cfg.l as u32,
    };
    Dims {
        m: spec.m as u32,
        n: spec.n as u32,
        k: spec.k as u32,
        rows_per_tile,
        d: cfg.d() as u32,
        h: cfg.h as u32,
    }
}

/// Analytic fault-free cycle count for a workload in a mode.
pub fn analytic_cycles(cfg: RedMuleConfig, spec: GemmSpec, mode: ExecMode) -> u64 {
    PhaseSchedule::accelerator(&dims_of(cfg, spec, mode)).accelerator_cycles()
}

// ------------------------------------------------------- phase schedule

/// One phase class of a hosted execution. The accelerator phases mirror
/// the schedule FSM's states ([`crate::redmule::scheduler`]); the host
/// phases cover the cluster-core work bracketing them (§3.2/§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseKind {
    /// Host: program + commit the shadowed register-file context
    /// (parity-protected builds pay the §3.2 one-time 120 cycles).
    ConfigStage,
    /// Accelerator: preload one tile's Y elements into the accumulators.
    LoadY,
    /// Accelerator: the tile's N-chunk compute waves.
    Compute,
    /// Accelerator: drain the last wave through the `d`-deep pipeline.
    Drain,
    /// Accelerator: stream the tile's accumulators out (ECC re-encode on
    /// protected builds — the staging of results back into the SECDED
    /// memory happens inside this phase's stores).
    StoreZ,
    /// Host: ABFT writeback verification (`m + k` checksum comparisons).
    AbftVerify,
    /// Host: one online-ABFT in-place correction.
    AbftCorrect,
}

/// One schedule entry: `cycles` consecutive cycles of `kind`, starting
/// after `start` cycles have elapsed (accelerator phases count
/// accelerator cycles from task start; host phases carry `start = 0` and
/// account host cycles instead).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Phase {
    pub kind: PhaseKind,
    /// M/K tile coordinates (accelerator phases; 0 for host phases).
    pub mt: u16,
    pub kt: u16,
    /// Absolute start offset: the phase covers cycles
    /// `start + 1 ..= start + cycles` of the task's 1-based stepping.
    pub start: u64,
    pub cycles: u64,
}

/// The closed-form per-phase schedule of one fault-free execution — the
/// refactored form of the old aggregate [`analytic_cycles`] total. The
/// two-level executor jumps across whole phases of this schedule instead
/// of stepping them, and sizes its cycle-accurate fault windows from the
/// phase geometry (e.g. [`PhaseSchedule::drain_depth`] bounds how long a
/// strike keeps propagating through the FMA pipeline).
#[derive(Debug, Clone)]
pub struct PhaseSchedule {
    pub phases: Vec<Phase>,
}

impl PhaseSchedule {
    /// The accelerator-only schedule of `dims`: per-tile LoadY → Compute
    /// → Drain → StoreZ, in the schedule FSM's tile order. The summed
    /// cycle count equals [`Scheduler::nominal_cycles`] exactly (pinned
    /// by `schedule_total_matches_nominal_cycles`).
    pub fn accelerator(dims: &Dims) -> Self {
        let mut phases = Vec::with_capacity((dims.tiles_m() * dims.tiles_k() * 4) as usize);
        let mut start = 0u64;
        let mut push = |kind, mt: u32, kt: u32, cycles: u64, start: &mut u64| {
            phases.push(Phase {
                kind,
                mt: mt as u16,
                kt: kt as u16,
                start: *start,
                cycles,
            });
            *start += cycles;
        };
        for mt in 0..dims.tiles_m() {
            for kt in 0..dims.tiles_k() {
                push(PhaseKind::LoadY, mt, kt, Scheduler::load_cycles(dims, mt, kt) as u64, &mut start);
                push(PhaseKind::Compute, mt, kt, dims.chunks_n() as u64 * dims.d as u64, &mut start);
                push(PhaseKind::Drain, mt, kt, dims.d as u64, &mut start);
                push(PhaseKind::StoreZ, mt, kt, Scheduler::store_cycles(dims, mt, kt) as u64, &mut start);
            }
        }
        Self { phases }
    }

    /// The full hosted schedule: ConfigStage, the accelerator phases,
    /// and — on checksum builds — the writeback AbftVerify pass. The
    /// host phases' cycle counts match what [`crate::cluster::System`]
    /// charges to `config_cycles` on the same build.
    pub fn hosted(cfg: RedMuleConfig, protection: Protection, spec: GemmSpec, mode: ExecMode) -> Self {
        // ABFT builds execute the augmented (m+1, n, k+1) task.
        let run_spec = if protection.has_abft_checksums() {
            GemmSpec::new(spec.m + 1, spec.n, spec.k + 1)
        } else {
            spec
        };
        // FT mode needs data-protection hardware; without it the
        // accelerator silently degrades to performance mode.
        let run_mode = if protection.has_data_protection() {
            mode
        } else {
            ExecMode::Performance
        };
        let mut sched = Self::accelerator(&dims_of(cfg, run_spec, run_mode));
        let config = Phase {
            kind: PhaseKind::ConfigStage,
            mt: 0,
            kt: 0,
            start: 0,
            cycles: if protection.has_control_protection() {
                CONFIG_PARITY_CYCLES
            } else {
                8
            },
        };
        sched.phases.insert(0, config);
        if protection.has_abft_checksums() {
            sched.phases.push(Phase {
                kind: PhaseKind::AbftVerify,
                mt: 0,
                kt: 0,
                start: 0,
                cycles: (run_spec.m + run_spec.k) as u64,
            });
        }
        sched
    }

    /// The host-phase entry of one online-ABFT in-place correction
    /// (appended to a schedule when the executor accounts a repair).
    pub fn abft_correct_phase() -> Phase {
        Phase {
            kind: PhaseKind::AbftCorrect,
            mt: 0,
            kt: 0,
            start: 0,
            cycles: ABFT_CORRECT_CYCLES,
        }
    }

    /// Total accelerator cycles (host phases excluded) — equals
    /// [`Scheduler::nominal_cycles`] for the same dims.
    pub fn accelerator_cycles(&self) -> u64 {
        self.phases
            .iter()
            .filter(|p| !Self::is_host_phase(p.kind))
            .map(|p| p.cycles)
            .sum()
    }

    /// Total host cycles (ConfigStage / AbftVerify / AbftCorrect).
    pub fn host_cycles(&self) -> u64 {
        self.phases
            .iter()
            .filter(|p| Self::is_host_phase(p.kind))
            .map(|p| p.cycles)
            .sum()
    }

    fn is_host_phase(kind: PhaseKind) -> bool {
        matches!(
            kind,
            PhaseKind::ConfigStage | PhaseKind::AbftVerify | PhaseKind::AbftCorrect
        )
    }

    /// The accelerator phase covering absolute (1-based) cycle `cycle`,
    /// or `None` past the end of the task.
    pub fn phase_at(&self, cycle: u64) -> Option<&Phase> {
        self.phases
            .iter()
            .filter(|p| !Self::is_host_phase(p.kind))
            .find(|p| cycle > p.start && cycle <= p.start + p.cycles)
    }

    /// The pipeline depth the schedule's Drain phases flush — the bound
    /// on how many cycles an in-flight corruption keeps propagating
    /// before it either retires into an accumulator or is gone. The
    /// two-level executor sizes its cycle-accurate window settling
    /// margin from this.
    pub fn drain_depth(&self) -> u64 {
        self.phases
            .iter()
            .filter(|p| p.kind == PhaseKind::Drain)
            .map(|p| p.cycles)
            .max()
            .unwrap_or(0)
    }
}

/// Peak and achieved throughput for a workload.
#[derive(Debug, Clone, Copy)]
pub struct Throughput {
    pub cycles: u64,
    pub macs: u64,
    /// MACs per cycle achieved.
    pub macs_per_cycle: f64,
    /// Utilization vs. the array's peak (L·H MACs/cycle).
    pub utilization: f64,
    /// GFLOPS at the published 500 MHz (2 FLOPs per MAC).
    pub gflops: f64,
}

pub fn throughput(cfg: RedMuleConfig, spec: GemmSpec, cycles: u64) -> Throughput {
    let macs = spec.macs();
    let mpc = macs as f64 / cycles.max(1) as f64;
    Throughput {
        cycles,
        macs,
        macs_per_cycle: mpc,
        utilization: mpc / cfg.macs_per_cycle() as f64,
        gflops: 2.0 * mpc * FREQ_MHZ / 1000.0,
    }
}

/// Measured cycles from the simulator (fault-free hosted run).
pub fn measured_cycles(
    cfg: RedMuleConfig,
    protection: Protection,
    spec: GemmSpec,
    mode: ExecMode,
) -> Result<u64> {
    let mut sys = System::new(cfg, protection);
    let p = GemmProblem::random(&spec, 0x9E37);
    let r = sys.run_gemm(&p, mode)?;
    Ok(r.cycles)
}

/// Expected per-workload cycle overhead of the retry mechanism given a
/// detection probability `p_retry` (from the campaign): a detected fault
/// aborts mid-flight (on average half the workload is lost) and triggers
/// reconfiguration plus a full re-execution.
pub fn retry_expected_overhead(base_cycles: u64, p_retry: f64) -> f64 {
    let c = base_cycles as f64;
    p_retry * (0.5 * c + CONFIG_PARITY_CYCLES as f64 + c)
}

/// One row of the mode-comparison report (the §4.1 performance claims).
#[derive(Debug, Clone)]
pub struct ModeReport {
    pub spec: GemmSpec,
    pub perf_cycles: u64,
    pub ft_cycles: u64,
    pub slowdown: f64,
    pub perf_util: f64,
    pub ft_util: f64,
}

pub fn mode_report(cfg: RedMuleConfig, protection: Protection, spec: GemmSpec) -> Result<ModeReport> {
    let perf = measured_cycles(cfg, protection, spec, ExecMode::Performance)?;
    let ft = measured_cycles(cfg, protection, spec, ExecMode::FaultTolerant)?;
    Ok(ModeReport {
        spec,
        perf_cycles: perf,
        ft_cycles: ft,
        slowdown: ft as f64 / perf as f64,
        perf_util: throughput(cfg, spec, perf).utilization,
        ft_util: throughput(cfg, spec, ft).utilization,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_matches_measured_for_paper_workload() {
        let cfg = RedMuleConfig::paper();
        let spec = GemmSpec::paper_workload();
        for (prot, mode) in [
            (Protection::Baseline, ExecMode::Performance),
            (Protection::Full, ExecMode::FaultTolerant),
            (Protection::Full, ExecMode::Performance),
        ] {
            let a = analytic_cycles(cfg, spec, if prot.has_data_protection() { mode } else { ExecMode::Performance });
            let m = measured_cycles(cfg, prot, spec, mode).unwrap();
            assert_eq!(a, m, "{prot:?}/{mode:?}");
        }
    }

    #[test]
    fn schedule_total_matches_nominal_cycles() {
        // The per-phase refactor of the aggregate total must not move a
        // single cycle: Σ phases == Scheduler::nominal_cycles on every
        // geometry × shape × mode combination the engine matrix uses.
        for cfg in [RedMuleConfig::paper(), RedMuleConfig::new(8, 2, 2)] {
            for spec in [
                GemmSpec::paper_workload(),
                GemmSpec::new(6, 8, 8),
                GemmSpec::new(1, 1, 1),
                GemmSpec::new(13, 17, 19),
                GemmSpec::new(32, 192, 48),
            ] {
                for mode in [ExecMode::Performance, ExecMode::FaultTolerant] {
                    let dims = dims_of(cfg, spec, mode);
                    let sched = PhaseSchedule::accelerator(&dims);
                    assert_eq!(
                        sched.accelerator_cycles(),
                        Scheduler::nominal_cycles(&dims),
                        "{spec:?}/{mode:?}"
                    );
                    assert_eq!(sched.host_cycles(), 0);
                    // Phases tile the cycle axis exactly: contiguous,
                    // gapless, covering 1..=total.
                    let mut expect_start = 0u64;
                    for p in &sched.phases {
                        assert_eq!(p.start, expect_start, "{p:?}");
                        expect_start += p.cycles;
                    }
                    let total = sched.accelerator_cycles();
                    assert!(sched.phase_at(0).is_none());
                    assert!(sched.phase_at(total + 1).is_none());
                    assert_eq!(sched.phase_at(1).unwrap().kind, PhaseKind::LoadY);
                    assert_eq!(sched.phase_at(total).unwrap().kind, PhaseKind::StoreZ);
                    assert_eq!(sched.drain_depth(), dims.d as u64);
                }
            }
        }
    }

    #[test]
    fn hosted_schedule_accounts_host_phases_like_the_cluster() {
        let cfg = RedMuleConfig::paper();
        let spec = GemmSpec::paper_workload();
        // Control-protected builds pay the §3.2 parity cycles up front.
        let full = PhaseSchedule::hosted(cfg, Protection::Full, spec, ExecMode::FaultTolerant);
        assert_eq!(full.phases[0].kind, PhaseKind::ConfigStage);
        assert_eq!(full.phases[0].cycles, CONFIG_PARITY_CYCLES);
        assert_eq!(full.host_cycles(), CONFIG_PARITY_CYCLES);
        let base = PhaseSchedule::hosted(cfg, Protection::Baseline, spec, ExecMode::Performance);
        assert_eq!(base.phases[0].cycles, 8);
        // ABFT builds append the writeback verification of the augmented
        // (m+1, k+1) task and run the augmented accelerator schedule.
        let abft = PhaseSchedule::hosted(cfg, Protection::Abft, spec, ExecMode::Performance);
        let last = abft.phases.last().unwrap();
        assert_eq!(last.kind, PhaseKind::AbftVerify);
        assert_eq!(last.cycles, (spec.m + 1 + spec.k + 1) as u64);
        let aug = GemmSpec::new(spec.m + 1, spec.n, spec.k + 1);
        assert_eq!(
            abft.accelerator_cycles(),
            analytic_cycles(cfg, aug, ExecMode::Performance)
        );
        assert_eq!(
            PhaseSchedule::abft_correct_phase().cycles,
            ABFT_CORRECT_CYCLES
        );
        // FT on a baseline build degrades to performance dims, exactly
        // like the latched-mode logic in the accelerator.
        let degraded =
            PhaseSchedule::hosted(cfg, Protection::Baseline, spec, ExecMode::FaultTolerant);
        assert_eq!(
            degraded.accelerator_cycles(),
            analytic_cycles(cfg, spec, ExecMode::Performance)
        );
    }

    #[test]
    fn ft_slowdown_approaches_2x_for_large_workloads() {
        let cfg = RedMuleConfig::paper();
        let r = mode_report(cfg, Protection::Full, GemmSpec::new(48, 96, 96)).unwrap();
        assert!(
            (1.8..=2.2).contains(&r.slowdown),
            "slowdown {:.2} should be ≈2 (perf={}, ft={})",
            r.slowdown,
            r.perf_cycles,
            r.ft_cycles
        );
    }

    #[test]
    fn utilization_is_high_in_steady_state() {
        // Large-N workloads amortize load/drain/store: utilization should
        // approach 1 MAC/CE/cycle in performance mode.
        let cfg = RedMuleConfig::paper();
        let spec = GemmSpec::new(12, 256, 12);
        let t = throughput(cfg, spec, analytic_cycles(cfg, spec, ExecMode::Performance));
        assert!(t.utilization > 0.7, "utilization {:.2}", t.utilization);
    }

    #[test]
    fn retry_overhead_scales_with_probability() {
        let base = 1000;
        assert_eq!(retry_expected_overhead(base, 0.0), 0.0);
        let at_12pct = retry_expected_overhead(base, 0.12);
        // ~12 % of runs pay ~1.5× the workload plus reconfiguration.
        assert!((150.0..=220.0).contains(&at_12pct), "{at_12pct}");
    }

    #[test]
    fn gflops_at_peak_matches_array_size() {
        let cfg = RedMuleConfig::paper();
        // Hypothetical perfect utilization: L·H MACs/cycle at 500 MHz.
        let spec = GemmSpec::new(12, 4096, 12);
        let cycles = spec.macs() / cfg.macs_per_cycle() as u64;
        let t = throughput(cfg, spec, cycles);
        assert!((t.gflops - 48.0).abs() < 0.5, "peak ≈ 48 GFLOPS, got {:.1}", t.gflops);
    }
}
