//! Fault-site registry: the sampling population for the SFI campaign.
//!
//! The paper injects single transient faults into **uniformly chosen
//! combinational nets** of the synthesized netlist (clock/reset excluded).
//! The simulator has no netlist, so the registry approximates uniform net
//! sampling with **area-weighted architectural-site sampling**: every
//! modelled signal/state site is enumerated with a weight proportional to
//! the gate-equivalent area of the logic it stands for (from
//! [`crate::area`]), normalized within its module group. A module that is
//! 30 % of the build's GE receives 30 % of the injections — the same
//! expectation a uniform draw over nets would give.
//!
//! The population depends on the *build* (baseline / data / full): replica
//! streamers, checker nets, parity registers etc. only exist — and only
//! absorb injections — when the corresponding hardware is present,
//! mirroring how the paper's three netlists differ.

use crate::area::{area_report, AreaReport};
use crate::fault::site::{
    accum_unit, ce_unit, checker_unit, ctrl_unit, fault_unit, regfile_unit, sched_unit,
    streamer_unit, wbuf_unit, xbuf_unit, Module, SiteId,
};
use crate::fault::{FaultKind, FaultPlan};
use crate::redmule::regfile::{CONTEXTS, WORDS};
use crate::redmule::streamer::STREAM_MODULES;
use crate::redmule::{Protection, RedMuleConfig};
use crate::util::rng::Xoshiro256;

/// Single-event-effect derating: the probability that a transient pulse on
/// a uniformly chosen net of the site's cone actually becomes an
/// architecturally visible corruption.
///
/// Gate-level SFI masks the large majority of injected SETs through
/// logical masking (the flipped net is off the sensitized path — e.g. most
/// internal nets of an FMA partial-product tree don't affect the rounded
/// result), latch-window masking (the pulse misses the capture edge) and
/// electrical attenuation. Our sites are *architectural* values, so
/// idle-site masking is modelled naturally but intra-cone masking is not;
/// these factors stand in for it, per manifestation kind. They are the
/// model's single calibration point against Table 1's baseline column and
/// are documented in DESIGN.md §5 — all *relative* claims (protection
/// ratios, who wins) are insensitive to them.
pub mod derating {
    use crate::fault::FaultKind;

    /// SET on a combinational cone: logical + latch-window masking.
    pub const SET_LATCH: f64 = 0.30;
    /// Corruption latched into a register. Lower than the SET factor
    /// because our SEU site classes summarize whole registers whose
    /// architectural lifetime (and hence effectiveness) the coarse model
    /// over-estimates relative to per-net netlist sampling.
    pub const SEU_LATCH: f64 = 0.10;

    #[inline]
    pub fn for_kind(kind: FaultKind) -> f64 {
        match kind {
            FaultKind::Transient => SET_LATCH,
            FaultKind::StateUpset => SEU_LATCH,
        }
    }
}

/// How the N faults of a multi-fault plan are correlated (the sweep
/// engine's fault-count axis; FT-GEMM and the online-ABFT GPU work both
/// evaluate ABFT under multi-error regimes, not just single SEUs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultModel {
    /// N independent single-event upsets: site, bit and cycle drawn
    /// independently for each fault.
    Independent,
    /// One multi-bit event: a single site/cycle draw with N adjacent bits
    /// corrupted — an MBU on a register, or an SET burst clipping
    /// neighbouring nets of one cone.
    Burst,
    /// One spatial event spanning N adjacent *sites*: an area-weighted
    /// anchor draw plus its physical neighbours in the population
    /// enumeration (instances are enumerated in spatial order within each
    /// unit), one shared cycle, an independent uniform bit per struck
    /// site. Models a particle strike clipping neighbouring registers /
    /// nets of different cones — the regime where per-site protection
    /// (ECC words, lockstep pairs) degrades fastest.
    SiteBurst,
}

impl FaultModel {
    pub fn name(self) -> &'static str {
        match self {
            FaultModel::Independent => "independent",
            FaultModel::Burst => "burst",
            FaultModel::SiteBurst => "site-burst",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "independent" | "seu" => Some(FaultModel::Independent),
            "burst" | "mbu" => Some(FaultModel::Burst),
            "site-burst" | "siteburst" | "site_burst" => Some(FaultModel::SiteBurst),
            _ => None,
        }
    }
}

/// One entry of the population: a site class instance with its bit width,
/// manifestation kind and sampling weight (kGE it stands for).
#[derive(Debug, Clone, Copy)]
pub struct SiteEntry {
    pub site: SiteId,
    pub bits: u8,
    pub kind: FaultKind,
    pub weight: f64,
}

// --------------------------------------------------------------- strata
//
// The stratified campaign engine partitions the population into a small
// number of architecturally meaningful strata so per-stratum injection
// counts can be allocated explicitly: under plain area-weighted sampling
// the CE datapath (the overwhelming majority of the gate count) absorbs
// almost every injection, and rare-but-critical populations — the
// register file, the scheduler/control FSMs, the ABFT checksum unit —
// are starved of samples exactly where outcome rates are most volatile.

/// Number of sampling strata in [`stratum_of_module`]'s partition.
pub const N_STRATA: usize = 5;

/// Stable display names of the strata, indexed by stratum id.
pub const STRATUM_NAMES: [&str; N_STRATA] =
    ["datapath", "streamer", "scheduler", "regfile", "checker"];

/// The stratum a module's sites belong to. Total over [`Module`]: every
/// site of every build lands in exactly one stratum.
pub fn stratum_of_module(m: Module) -> usize {
    match m {
        Module::CeArray | Module::XBuf | Module::WBuf | Module::Accumulator => 0,
        Module::StreamerX
        | Module::StreamerW
        | Module::StreamerY
        | Module::StreamerZ
        | Module::StreamerReplica => 1,
        Module::SchedFsm | Module::CtrlFsm | Module::FsmReplica => 2,
        Module::RegFile | Module::RegParity => 3,
        Module::Checker | Module::FaultUnit => 4,
    }
}

/// Per-stratum slice of the population: the entry indices (in enumeration
/// order) with their cumulative weights for O(log n) in-stratum sampling.
#[derive(Debug, Clone, Default)]
struct StratumPop {
    indices: Vec<u32>,
    cum: Vec<f64>,
    weight: f64,
}

/// The complete, weighted site population for one build.
#[derive(Debug, Clone)]
pub struct FaultRegistry {
    pub cfg: RedMuleConfig,
    pub protection: Protection,
    entries: Vec<SiteEntry>,
    /// Cumulative weights for O(log n) sampling.
    cum: Vec<f64>,
    total_weight: f64,
    /// Stratum partition of `entries` (see [`stratum_of_module`]).
    strata: Vec<StratumPop>,
}

/// Intermediate builder: collects entries of one module group, then
/// normalizes their weights to the group's kGE share.
struct Group {
    entries: Vec<(SiteId, u8, FaultKind)>,
    kge: f64,
}

impl Group {
    fn new(kge: f64) -> Self {
        Self {
            entries: Vec::new(),
            kge,
        }
    }

    fn add(&mut self, site: SiteId, bits: u8, kind: FaultKind) {
        self.entries.push((site, bits, kind));
    }

    fn add_range(
        &mut self,
        module: Module,
        unit: u8,
        indices: std::ops::Range<u32>,
        bits: u8,
        kind: FaultKind,
    ) {
        for i in indices {
            self.add(SiteId::with_wide_index(module, unit, i), bits, kind);
        }
    }

    /// Emit entries whose weights sum to the group's kGE, split by kind:
    ///
    /// * **state (SEU) sites** carry exactly their flip-flop area
    ///   (`bits × GE_PER_FF_BIT`) — a register bit is a register bit,
    ///   regardless of how much combinational logic surrounds it;
    /// * **net (SET) sites** share the *rest* of the group's gates
    ///   uniformly per modelled bit — they stand for the whole
    ///   combinational cone that the architectural net summarizes.
    ///
    /// Pure-register groups (accumulators, operand buffers, pipeline
    /// registers) keep their full GE on the SEU sites.
    fn finish(self, out: &mut Vec<SiteEntry>) {
        use crate::area::coeff::GE_PER_FF_BIT;
        let seu_bits: f64 = self
            .entries
            .iter()
            .filter(|e| e.2 == crate::fault::FaultKind::StateUpset)
            .map(|e| e.1 as f64)
            .sum();
        let set_bits: f64 = self
            .entries
            .iter()
            .filter(|e| e.2 == crate::fault::FaultKind::Transient)
            .map(|e| e.1 as f64)
            .sum();
        if (seu_bits + set_bits) == 0.0 || self.kge <= 0.0 {
            return;
        }
        let ff_kge = seu_bits * GE_PER_FF_BIT / 1000.0;
        let (seu_kge, set_kge) = if set_bits == 0.0 {
            (self.kge, 0.0)
        } else {
            // Cap so a register-heavy mixed group cannot starve its nets.
            let s = ff_kge.min(0.8 * self.kge);
            (s, self.kge - s)
        };
        let seu_per_bit = if seu_bits > 0.0 { seu_kge / seu_bits } else { 0.0 };
        let set_per_bit = if set_bits > 0.0 { set_kge / set_bits } else { 0.0 };
        out.extend(self.entries.into_iter().filter_map(|(site, bits, kind)| {
            let per_bit = match kind {
                crate::fault::FaultKind::StateUpset => seu_per_bit,
                crate::fault::FaultKind::Transient => set_per_bit,
            };
            let weight = per_bit * bits as f64;
            (weight > 0.0).then_some(SiteEntry {
                site,
                bits,
                kind,
                weight,
            })
        }));
    }
}

impl FaultRegistry {
    /// Enumerate the population for a build.
    pub fn new(cfg: RedMuleConfig, protection: Protection) -> Self {
        let report = area_report(cfg, protection);
        let kge = |prefix: &str| -> f64 {
            report
                .items
                .iter()
                .filter(|i| i.name.starts_with(prefix))
                .map(|i| i.kge)
                .sum()
        };

        let l = cfg.l as u32;
        let h = cfg.h as u32;
        let d = cfg.d() as u32;
        let n_ce = (cfg.l * cfg.h) as u32;
        let mut entries = Vec::new();
        use FaultKind::{StateUpset, Transient};

        // --- CE datapath: FMA / operand nets carry the FMA-logic weight.
        let mut g = Group::new(kge("ce_array/fma"));
        g.add_range(Module::CeArray, ce_unit::FMA_NET, 0..n_ce, 16, Transient);
        g.add_range(Module::CeArray, ce_unit::X_NET, 0..n_ce, 16, Transient);
        g.add_range(Module::CeArray, ce_unit::W_NET, 0..n_ce, 16, Transient);
        g.finish(&mut entries);

        // --- CE pipeline registers.
        let mut g = Group::new(kge("ce_array/pipe_regs"));
        g.add_range(Module::CeArray, ce_unit::PIPE_REG, 0..(l * d), 16, StateUpset);
        g.finish(&mut entries);

        // --- Accumulators.
        let mut g = Group::new(kge("accumulator"));
        g.add_range(Module::Accumulator, accum_unit::REG, 0..(l * d), 16, StateUpset);
        g.finish(&mut entries);

        // --- X operand registers (both banks).
        let mut g = Group::new(kge("xbuf"));
        g.add_range(Module::XBuf, xbuf_unit::REG, 0..(2 * n_ce), 16, StateUpset);
        g.finish(&mut entries);

        // --- W broadcast registers (+ parity regs and the pre-parity net
        //     when the data-path protection exists).
        // The W broadcast registers live for a single cycle between
        // refresh and use, so corruption manifests on the read path —
        // transient sites at the register outputs (the FaultCtx hooks in
        // `do_compute`), not latched upsets.
        let mut g = Group::new(kge("wbuf") + kge("ft/w_parity"));
        g.add_range(Module::WBuf, wbuf_unit::VALUE_REG, 0..h, 16, Transient);
        if protection.has_data_protection() {
            g.add_range(Module::WBuf, wbuf_unit::PARITY_REG, 0..h, 1, Transient);
            g.add_range(Module::WBuf, wbuf_unit::PRE_PARITY_NET, 0..h, 16, Transient);
        }
        g.finish(&mut entries);

        // --- Primary streamers: address generators (latched masks), the
        //     request nets, response nets and (protected) decoder outputs,
        //     plus the Z store path. The streamer group also absorbs the
        //     data-protection extras (ECC codecs, addrgen complexity).
        let stream_kge = kge("streamer") + kge("ft/ecc_codecs") + kge("ft/addrgen_extra");
        let per_stream = stream_kge / 4.0;
        for (s, module) in STREAM_MODULES.iter().enumerate() {
            let mut g = Group::new(per_stream);
            g.add(
                SiteId::new(*module, streamer_unit::ADDR_REG, 0),
                32,
                StateUpset,
            );
            // Request-net lanes actually exercised by the model.
            let req_lanes = match s {
                0 => 64.min(l * h.min(16)).max(1), // X: one net per (row, col) pair
                1 => h,                            // W: one per CE column
                _ => 16,                           // Y/Z: wide-port beats
            };
            g.add_range(*module, streamer_unit::REQ_NET, 0..req_lanes, 32, Transient);
            // Response nets: raw codeword width when ECC is decoded here.
            let resp_bits = if protection.has_data_protection() { 39 } else { 16 };
            let resp_lanes = if s == 1 { h } else { 16.min(req_lanes).max(1) };
            g.add_range(*module, streamer_unit::RESP_NET, 0..resp_lanes, resp_bits, Transient);
            if protection.has_data_protection() && s != 1 {
                // Per-consumer-row decoder outputs (X/Y/Z paths).
                g.add_range(*module, streamer_unit::DEC_NET, 0..l, 16, Transient);
            }
            if s == 3 {
                // Z store nets: primary copy, redundant copy, post-checker.
                g.add_range(*module, streamer_unit::STORE_NET, 0..16, 16, Transient);
                if protection.has_data_protection() {
                    g.add_range(*module, streamer_unit::STORE_NET, 16..32, 16, Transient);
                }
                g.add_range(*module, streamer_unit::STORE_NET, 32..48, 16, Transient);
            }
            g.finish(&mut entries);
        }

        // --- FP8 cast units (hybrid-format builds only): the fetch-path
        //     cast-in unit of each operand stream and the store-path
        //     cast-out unit on Z. Each contributes its 8-bit code nets
        //     (SET; one lane per consumer row / CE column / store lane —
        //     matching the hook indices in the model) and one 8-bit
        //     code-holding register (SEU, single-beat lifetime). Datapath
        //     area, not FT overhead: it exists on *every* protection
        //     build of an FP8 task and widens the unprotected
        //     cross-section.
        if cfg.format.is_fp8() {
            let castin: [(Module, &str, u32); 3] = [
                (Module::StreamerX, "dp/castin_x", l),
                (Module::StreamerW, "dp/castin_w", h),
                (Module::StreamerY, "dp/castin_y", l),
            ];
            for (module, item, lanes) in castin {
                let mut g = Group::new(kge(item));
                g.add_range(module, streamer_unit::CASTIN_NET, 0..lanes, 8, Transient);
                g.add(
                    SiteId::new(module, streamer_unit::CASTIN_REG, 0),
                    8,
                    StateUpset,
                );
                g.finish(&mut entries);
            }
            let mut g = Group::new(kge("dp/castout_z"));
            g.add_range(
                Module::StreamerZ,
                streamer_unit::CASTOUT_NET,
                0..16,
                8,
                Transient,
            );
            g.add(
                SiteId::new(Module::StreamerZ, streamer_unit::CASTOUT_REG, 0),
                8,
                StateUpset,
            );
            g.finish(&mut entries);
        }

        // --- Scheduler FSM + its control nets to the rows.
        let mut g = Group::new(kge("sched_fsm"));
        g.add(SiteId::new(Module::SchedFsm, sched_unit::STATE_REG, 0), 3, StateUpset);
        g.add_range(Module::SchedFsm, sched_unit::COUNT_REG, 0..5, 16, StateUpset);
        g.add_range(Module::SchedFsm, sched_unit::CTRL_NET, 0..l, 1, Transient);
        g.finish(&mut entries);

        // --- Control FSM.
        let mut g = Group::new(kge("ctrl_fsm"));
        g.add(SiteId::new(Module::CtrlFsm, ctrl_unit::STATE_REG, 0), 3, StateUpset);
        g.finish(&mut entries);

        // --- Register file words (+ parity bits in the Full build).
        let mut g = Group::new(kge("regfile") + kge("ft/regfile_parity"));
        g.add_range(
            Module::RegFile,
            regfile_unit::WORD,
            0..(CONTEXTS * WORDS) as u32,
            32,
            StateUpset,
        );
        if protection.has_control_protection() {
            g.add_range(
                Module::RegFile,
                regfile_unit::PARITY,
                0..(CONTEXTS * WORDS) as u32,
                1,
                StateUpset,
            );
        }
        g.finish(&mut entries);

        // --- Fault unit: status registers + the interrupt wire.
        let mut g = Group::new(kge("ft/fault_tracking") + kge("ft/irq_logic") + 0.4);
        g.add(SiteId::new(Module::FaultUnit, fault_unit::STATUS_REG, 0), 7, StateUpset);
        g.add(SiteId::new(Module::FaultUnit, fault_unit::IRQ_NET, 0), 1, Transient);
        g.finish(&mut entries);

        // --- ABFT checksum unit: store-path taps + accumulator bank.
        if protection.has_abft_checksums() {
            let mut g = Group::new(kge("ft/abft"));
            g.add_range(
                Module::Checker,
                checker_unit::ABFT_TAP_NET,
                0..16,
                16,
                Transient,
            );
            g.add_range(
                Module::Checker,
                checker_unit::ABFT_ACC_REG,
                0..(l + d),
                crate::redmule::abft::ABFT_ACC_BITS,
                StateUpset,
            );
            g.finish(&mut entries);
        }

        // --- Online-ABFT residual unit: pre-store taps + residual bank
        // (`AbftOnline` only; the `ft/online_abft` prefix is deliberately
        // disjoint from `ft/abft` so the base group's area weight is not
        // double-counted).
        if protection.has_online_abft() {
            let mut g = Group::new(kge("ft/online_abft"));
            g.add_range(
                Module::Checker,
                checker_unit::ABFT_ONLINE_TAP_NET,
                0..16,
                16,
                Transient,
            );
            g.add_range(
                Module::Checker,
                checker_unit::ABFT_RES_REG,
                0..(l + d),
                crate::redmule::abft::ABFT_ACC_BITS,
                StateUpset,
            );
            g.finish(&mut entries);
        }

        // --- [8]-style per-CE checker comparison nets.
        if protection.has_per_ce_checkers() {
            let mut g = Group::new(kge("ft/perce_checkers"));
            g.add_range(
                Module::Checker,
                checker_unit::PERCE_CMP_NET,
                0..n_ce,
                1,
                Transient,
            );
            g.finish(&mut entries);
        }

        // --- Checkers + write filter (data protection).
        if protection.has_data_protection() {
            let mut g = Group::new(kge("ft/z_checkers") + kge("ft/write_filter"));
            g.add_range(Module::Checker, checker_unit::Z_CMP_NET, 0..(l / 2).max(1), 1, Transient);
            g.add_range(Module::Checker, checker_unit::WFILTER_NET, 0..16, 1, Transient);
            g.finish(&mut entries);
        }

        // --- Replica streamers + replica FSMs (full protection).
        if protection.has_control_protection() {
            let rep_kge = kge("ft/replica_streamers");
            let per_rep = rep_kge / 4.0;
            for s in 0..4usize {
                let mut g = Group::new(per_rep);
                // Replica address-generator state (unit = stream*2).
                g.add(
                    SiteId::new(Module::StreamerReplica, (s * 2) as u8, 0),
                    32,
                    StateUpset,
                );
                // Replica request nets (unit = stream*2+1).
                let req_lanes = match s {
                    0 => 64.min(l * h.min(16)).max(1),
                    1 => h,
                    _ => 16,
                };
                g.add_range(
                    Module::StreamerReplica,
                    (s * 2 + 1) as u8,
                    0..req_lanes,
                    32,
                    Transient,
                );
                g.finish(&mut entries);
            }

            let mut g = Group::new(kge("ft/replica_fsms") + kge("ft/fsm_comparators"));
            g.add(SiteId::new(Module::FsmReplica, 0, 0), 3, StateUpset); // sched phase
            g.add_range(Module::FsmReplica, 1, 0..5, 16, StateUpset); // sched counters
            g.add(SiteId::new(Module::FsmReplica, 2, 0), 3, StateUpset); // ctrl state
            g.finish(&mut entries);
        }

        let mut cum = Vec::with_capacity(entries.len());
        let mut acc = 0.0;
        let mut strata = vec![StratumPop::default(); N_STRATA];
        for (i, e) in entries.iter().enumerate() {
            acc += e.weight;
            cum.push(acc);
            let s = &mut strata[stratum_of_module(e.site.module())];
            s.weight += e.weight;
            s.indices.push(i as u32);
            s.cum.push(s.weight);
        }
        Self {
            cfg,
            protection,
            entries,
            cum,
            total_weight: acc,
            strata,
        }
    }

    pub fn entries(&self) -> &[SiteEntry] {
        &self.entries
    }

    pub fn n_entries(&self) -> usize {
        self.entries.len()
    }

    /// Total population weight (≈ the build's modelled kGE, minus glue).
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    /// Total number of injectable bits.
    pub fn total_bits(&self) -> u64 {
        self.entries.iter().map(|e| e.bits as u64).sum()
    }

    /// Area-weighted random population index (one `next_f64` draw).
    fn sample_index(&self, rng: &mut Xoshiro256) -> usize {
        let t = rng.next_f64() * self.total_weight;
        self.cum.partition_point(|&c| c < t).min(self.entries.len() - 1)
    }

    /// Area-weighted random site entry.
    pub fn sample_entry(&self, rng: &mut Xoshiro256) -> &SiteEntry {
        &self.entries[self.sample_index(rng)]
    }

    /// Draw one complete fault plan: area-weighted site, uniform bit,
    /// uniform cycle in `[1, horizon]`.
    pub fn sample_plan(&self, horizon: u64, rng: &mut Xoshiro256) -> FaultPlan {
        let e = self.sample_entry(rng);
        FaultPlan {
            cycle: 1 + rng.below(horizon.max(1)),
            site: e.site,
            bit: rng.below(e.bits as u64) as u8,
            kind: e.kind,
        }
    }

    /// Draw a multi-fault plan of `n ≥ 1` faults into `out` (cleared
    /// first; the campaign reuses the buffer across runs). `Independent`
    /// plans are `n` separate [`FaultRegistry::sample_plan`] draws;
    /// `Burst` plans share one site/cycle draw and corrupt `n` adjacent
    /// bits (capped at the site's width, so a burst never repeats a bit);
    /// `SiteBurst` plans share one cycle draw and strike `n` adjacent
    /// *sites* of the population starting at an area-weighted anchor
    /// (clipped at the end of the enumeration, so a burst never wraps
    /// onto an unrelated module), one uniform bit per site.
    /// Consumes RNG draws in a fixed order — fully deterministic.
    pub fn sample_plans_into(
        &self,
        horizon: u64,
        n: usize,
        model: FaultModel,
        rng: &mut Xoshiro256,
        out: &mut Vec<FaultPlan>,
    ) {
        out.clear();
        match model {
            FaultModel::Independent => {
                for _ in 0..n {
                    out.push(self.sample_plan(horizon, rng));
                }
            }
            FaultModel::Burst => {
                let e = self.sample_entry(rng);
                let cycle = 1 + rng.below(horizon.max(1));
                let start = rng.below(e.bits as u64) as u32;
                let width = n.min(e.bits as usize) as u32;
                for j in 0..width {
                    out.push(FaultPlan {
                        cycle,
                        site: e.site,
                        bit: ((start + j) % e.bits as u32) as u8,
                        kind: e.kind,
                    });
                }
            }
            FaultModel::SiteBurst => {
                let anchor = self.sample_index(rng);
                let cycle = 1 + rng.below(horizon.max(1));
                let end = (anchor + n).min(self.entries.len());
                for e in &self.entries[anchor..end] {
                    out.push(FaultPlan {
                        cycle,
                        site: e.site,
                        bit: rng.below(e.bits as u64) as u8,
                        kind: e.kind,
                    });
                }
            }
        }
    }

    /// Allocating convenience wrapper around
    /// [`FaultRegistry::sample_plans_into`].
    pub fn sample_plans(
        &self,
        horizon: u64,
        n: usize,
        model: FaultModel,
        rng: &mut Xoshiro256,
    ) -> Vec<FaultPlan> {
        let mut out = Vec::with_capacity(n);
        self.sample_plans_into(horizon, n, model, rng, &mut out);
        out
    }

    /// The area report used for the weighting (for reporting).
    pub fn area(&self) -> AreaReport {
        area_report(self.cfg, self.protection)
    }

    // --------------------------------------------- stratified sampling

    /// Number of strata of the partition (fixed; some may be empty on a
    /// given build).
    pub fn n_strata(&self) -> usize {
        N_STRATA
    }

    /// Display name of stratum `s`.
    pub fn stratum_name(s: usize) -> &'static str {
        STRATUM_NAMES[s]
    }

    /// Summed sampling weight of stratum `s` (kGE it stands for).
    pub fn stratum_weight(&self, s: usize) -> f64 {
        self.strata[s].weight
    }

    /// Normalized share of the population weight in stratum `s` — the
    /// `W_h` of the stratified estimator.
    pub fn stratum_share(&self, s: usize) -> f64 {
        if self.total_weight > 0.0 {
            self.strata[s].weight / self.total_weight
        } else {
            0.0
        }
    }

    /// Number of population entries in stratum `s`.
    pub fn stratum_len(&self, s: usize) -> usize {
        self.strata[s].indices.len()
    }

    /// Area-weighted random population index *within* stratum `s` (one
    /// `next_f64` draw); `None` when the stratum is empty on this build.
    fn sample_index_in_stratum(&self, s: usize, rng: &mut Xoshiro256) -> Option<usize> {
        let sp = &self.strata[s];
        if sp.indices.is_empty() || sp.weight <= 0.0 {
            return None;
        }
        let t = rng.next_f64() * sp.weight;
        let pos = sp.cum.partition_point(|&c| c < t).min(sp.indices.len() - 1);
        Some(sp.indices[pos] as usize)
    }

    /// Draw one fault plan with the site restricted to stratum `s`
    /// (area-weighted within the stratum; bit and cycle as in
    /// [`FaultRegistry::sample_plan`]). `None` when the stratum is empty.
    pub fn sample_plan_in_stratum(
        &self,
        horizon: u64,
        s: usize,
        rng: &mut Xoshiro256,
    ) -> Option<FaultPlan> {
        let idx = self.sample_index_in_stratum(s, rng)?;
        let e = &self.entries[idx];
        Some(FaultPlan {
            cycle: 1 + rng.below(horizon.max(1)),
            site: e.site,
            bit: rng.below(e.bits as u64) as u8,
            kind: e.kind,
        })
    }

    /// Stratified counterpart of [`FaultRegistry::sample_plans_into`]:
    /// the site draw (every draw for `Independent`, the single event
    /// anchor for `Burst`/`SiteBurst`) is restricted to stratum `s`. A
    /// `SiteBurst` anchored in the stratum still spans its *physical*
    /// neighbours in the global enumeration — adjacency is a property of
    /// the layout, not of the sampling design. Leaves `out` empty when
    /// the stratum is empty on this build.
    pub fn sample_plans_in_stratum_into(
        &self,
        horizon: u64,
        n: usize,
        model: FaultModel,
        s: usize,
        rng: &mut Xoshiro256,
        out: &mut Vec<FaultPlan>,
    ) {
        out.clear();
        match model {
            FaultModel::Independent => {
                for _ in 0..n {
                    match self.sample_plan_in_stratum(horizon, s, rng) {
                        Some(p) => out.push(p),
                        None => return,
                    }
                }
            }
            FaultModel::Burst => {
                let Some(idx) = self.sample_index_in_stratum(s, rng) else {
                    return;
                };
                let e = &self.entries[idx];
                let cycle = 1 + rng.below(horizon.max(1));
                let start = rng.below(e.bits as u64) as u32;
                let width = n.min(e.bits as usize) as u32;
                for j in 0..width {
                    out.push(FaultPlan {
                        cycle,
                        site: e.site,
                        bit: ((start + j) % e.bits as u32) as u8,
                        kind: e.kind,
                    });
                }
            }
            FaultModel::SiteBurst => {
                let Some(anchor) = self.sample_index_in_stratum(s, rng) else {
                    return;
                };
                let cycle = 1 + rng.below(horizon.max(1));
                let end = (anchor + n).min(self.entries.len());
                for e in &self.entries[anchor..end] {
                    out.push(FaultPlan {
                        cycle,
                        site: e.site,
                        bit: rng.below(e.bits as u64) as u8,
                        kind: e.kind,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg(p: Protection) -> FaultRegistry {
        FaultRegistry::new(RedMuleConfig::paper(), p)
    }

    #[test]
    fn population_grows_with_protection() {
        let b = reg(Protection::Baseline);
        let d = reg(Protection::Data);
        let f = reg(Protection::Full);
        assert!(d.n_entries() > b.n_entries());
        assert!(f.n_entries() > d.n_entries());
        assert!(f.total_weight() > d.total_weight());
        assert!(d.total_weight() > b.total_weight());
    }

    #[test]
    fn abft_population_is_baseline_plus_checksum_unit() {
        let b = reg(Protection::Baseline);
        let a = reg(Protection::Abft);
        // 16 tap nets + L+D accumulator registers on the paper instance.
        assert_eq!(a.n_entries(), b.n_entries() + 16 + 24);
        assert!(a.total_weight() > b.total_weight());
        assert!(
            a.entries()
                .iter()
                .any(|e| e.site.module() == Module::Checker
                    && e.site.unit() == crate::fault::site::checker_unit::ABFT_ACC_REG
                    && e.kind == FaultKind::StateUpset),
            "accumulator SEU sites must be in the population"
        );
    }

    #[test]
    fn perce_population_is_baseline_plus_checkers() {
        let b = reg(Protection::Baseline);
        let p = reg(Protection::PerCe);
        assert_eq!(
            p.n_entries(),
            b.n_entries() + 48,
            "one checker net per CE on the paper instance"
        );
        assert!(p.total_weight() > b.total_weight());
    }

    #[test]
    fn fp8_population_adds_cast_sites_in_the_streamer_stratum() {
        use crate::fp::{Fp8Format, GemmFormat};
        let cfg8 = RedMuleConfig::paper().with_format(GemmFormat::Fp8(Fp8Format::E4M3));
        for p in [Protection::Baseline, Protection::Full, Protection::Abft] {
            let f16 = FaultRegistry::new(RedMuleConfig::paper(), p);
            let f8 = FaultRegistry::new(cfg8, p);
            // Paper instance: (12 + 1) + (4 + 1) + (12 + 1) cast-in sites
            // plus 16 + 1 cast-out sites.
            assert_eq!(f8.n_entries(), f16.n_entries() + 48, "{p:?}");
            assert!(f8.total_weight() > f16.total_weight(), "{p:?}");
            let cast_units = [
                crate::fault::site::streamer_unit::CASTIN_NET,
                crate::fault::site::streamer_unit::CASTIN_REG,
                crate::fault::site::streamer_unit::CASTOUT_NET,
                crate::fault::site::streamer_unit::CASTOUT_REG,
            ];
            assert!(
                !f16.entries().iter().any(|e| matches!(
                    e.site.module(),
                    Module::StreamerX | Module::StreamerW | Module::StreamerY | Module::StreamerZ
                ) && cast_units.contains(&e.site.unit())),
                "{p:?}: FP16 population must not contain cast sites"
            );
            for e in f8.entries() {
                let is_cast = cast_units.contains(&e.site.unit())
                    && matches!(
                        e.site.module(),
                        Module::StreamerX
                            | Module::StreamerW
                            | Module::StreamerY
                            | Module::StreamerZ
                    );
                if is_cast {
                    assert_eq!(e.bits, 8, "cast sites are 8-bit codes");
                    assert_eq!(
                        stratum_of_module(e.site.module()),
                        1,
                        "cast sites land in the streamer stratum"
                    );
                }
            }
        }
    }

    #[test]
    fn baseline_has_no_ft_sites() {
        let b = reg(Protection::Baseline);
        for e in b.entries() {
            assert!(
                !matches!(
                    e.site.module(),
                    Module::Checker | Module::StreamerReplica | Module::FsmReplica
                ),
                "baseline population must not contain {:?}",
                e.site.module()
            );
        }
    }

    #[test]
    fn full_build_samples_replica_sites() {
        let f = reg(Protection::Full);
        let mut rng = Xoshiro256::new(7);
        let mut saw_replica = false;
        for _ in 0..20_000 {
            let e = f.sample_entry(&mut rng);
            if matches!(e.site.module(), Module::StreamerReplica | Module::FsmReplica) {
                saw_replica = true;
                break;
            }
        }
        assert!(saw_replica, "replica sites must be reachable by sampling");
    }

    #[test]
    fn sampling_tracks_area_weights() {
        // The CE-datapath share of samples should match its weight share
        // within a few percent over a large draw.
        let b = reg(Protection::Baseline);
        let ce_weight: f64 = b
            .entries()
            .iter()
            .filter(|e| e.site.module() == Module::CeArray)
            .map(|e| e.weight)
            .sum();
        let expect = ce_weight / b.total_weight();
        let mut rng = Xoshiro256::new(99);
        let n = 200_000;
        let mut hits = 0u64;
        for _ in 0..n {
            if b.sample_entry(&mut rng).site.module() == Module::CeArray {
                hits += 1;
            }
        }
        let got = hits as f64 / n as f64;
        assert!(
            (got - expect).abs() < 0.01,
            "CE share sampled {got:.3} vs expected {expect:.3}"
        );
    }

    #[test]
    fn plans_are_in_bounds() {
        let f = reg(Protection::Full);
        let mut rng = Xoshiro256::new(3);
        for _ in 0..10_000 {
            let p = f.sample_plan(500, &mut rng);
            assert!(p.cycle >= 1 && p.cycle <= 500);
            let e = f
                .entries()
                .iter()
                .find(|e| e.site == p.site)
                .expect("sampled site must be in the population");
            assert!(p.bit < e.bits);
        }
    }

    #[test]
    fn independent_multi_plans_are_n_separate_draws() {
        let f = reg(Protection::Full);
        for n in [1usize, 2, 3, 5] {
            let mut r1 = Xoshiro256::new(42);
            let mut r2 = Xoshiro256::new(42);
            let a = f.sample_plans(300, n, FaultModel::Independent, &mut r1);
            let b = f.sample_plans(300, n, FaultModel::Independent, &mut r2);
            assert_eq!(a, b, "same seed must reproduce the plan");
            assert_eq!(a.len(), n);
            for p in &a {
                assert!(p.cycle >= 1 && p.cycle <= 300);
            }
        }
        // n = 1 consumes exactly the draws of a single sample_plan.
        let mut r1 = Xoshiro256::new(9);
        let mut r2 = Xoshiro256::new(9);
        let single = f.sample_plan(300, &mut r1);
        let multi = f.sample_plans(300, 1, FaultModel::Independent, &mut r2);
        assert_eq!(multi, vec![single]);
        assert_eq!(r1.next_u64(), r2.next_u64(), "RNGs must stay in lockstep");
    }

    #[test]
    fn burst_plans_share_site_and_cycle_with_distinct_adjacent_bits() {
        let f = reg(Protection::Full);
        let mut rng = Xoshiro256::new(7);
        for _ in 0..500 {
            let plans = f.sample_plans(200, 3, FaultModel::Burst, &mut rng);
            assert!(!plans.is_empty() && plans.len() <= 3);
            let entry = f
                .entries()
                .iter()
                .find(|e| e.site == plans[0].site)
                .expect("burst site must be in the population");
            assert_eq!(plans.len(), 3.min(entry.bits as usize));
            let mut bits: Vec<u8> = plans
                .iter()
                .map(|p| {
                    assert_eq!(p.site, plans[0].site, "one event, one site");
                    assert_eq!(p.cycle, plans[0].cycle, "one event, one cycle");
                    assert_eq!(p.kind, plans[0].kind);
                    assert!(p.bit < entry.bits);
                    p.bit
                })
                .collect();
            bits.sort_unstable();
            bits.dedup();
            assert_eq!(bits.len(), plans.len(), "burst bits must be distinct");
        }
    }

    #[test]
    fn fault_model_names_round_trip() {
        for m in [
            FaultModel::Independent,
            FaultModel::Burst,
            FaultModel::SiteBurst,
        ] {
            assert_eq!(FaultModel::parse(m.name()), Some(m));
        }
        assert_eq!(FaultModel::parse("mbu"), Some(FaultModel::Burst));
        assert_eq!(FaultModel::parse("siteburst"), Some(FaultModel::SiteBurst));
        assert_eq!(FaultModel::parse("nope"), None);
    }

    #[test]
    fn site_burst_plans_span_adjacent_population_entries() {
        let f = reg(Protection::Full);
        let mut rng = Xoshiro256::new(21);
        for _ in 0..500 {
            let plans = f.sample_plans(200, 3, FaultModel::SiteBurst, &mut rng);
            assert!(!plans.is_empty() && plans.len() <= 3);
            let anchor = f
                .entries()
                .iter()
                .position(|e| e.site == plans[0].site)
                .expect("anchor site must be in the population");
            // Clipping at the population end is the only reason for a
            // short burst.
            assert_eq!(plans.len(), 3.min(f.n_entries() - anchor));
            for (j, p) in plans.iter().enumerate() {
                let e = &f.entries()[anchor + j];
                assert_eq!(p.site, e.site, "plan {j} must strike entry {}", anchor + j);
                assert_eq!(p.cycle, plans[0].cycle, "one event, one cycle");
                assert_eq!(p.kind, e.kind, "each site keeps its own kind");
                assert!(p.bit < e.bits, "bit in range for its own site");
            }
        }
    }

    #[test]
    fn site_burst_sampling_is_deterministic_and_area_weighted() {
        let f = reg(Protection::Baseline);
        let mut r1 = Xoshiro256::new(5);
        let mut r2 = Xoshiro256::new(5);
        let a = f.sample_plans(300, 4, FaultModel::SiteBurst, &mut r1);
        let b = f.sample_plans(300, 4, FaultModel::SiteBurst, &mut r2);
        assert_eq!(a, b, "same seed must reproduce the burst");
        assert_eq!(r1.next_u64(), r2.next_u64(), "RNGs must stay in lockstep");
        // The anchor distribution follows the area weights, like single
        // draws: CE-datapath share within a few percent over a large draw.
        let ce_weight: f64 = f
            .entries()
            .iter()
            .filter(|e| e.site.module() == Module::CeArray)
            .map(|e| e.weight)
            .sum();
        let expect = ce_weight / f.total_weight();
        let mut rng = Xoshiro256::new(87);
        let n = 100_000;
        let mut hits = 0u64;
        let mut plans = Vec::new();
        for _ in 0..n {
            f.sample_plans_into(100, 2, FaultModel::SiteBurst, &mut rng, &mut plans);
            if plans[0].site.module() == Module::CeArray {
                hits += 1;
            }
        }
        let got = hits as f64 / n as f64;
        assert!(
            (got - expect).abs() < 0.015,
            "site-burst anchor share {got:.3} vs expected {expect:.3}"
        );
    }

    #[test]
    fn weights_are_positive_and_finite() {
        for p in [Protection::Baseline, Protection::Data, Protection::Full] {
            for e in reg(p).entries() {
                assert!(e.weight.is_finite() && e.weight > 0.0);
                assert!(e.bits > 0);
            }
        }
    }

    #[test]
    fn strata_partition_the_population() {
        for p in [
            Protection::Baseline,
            Protection::Data,
            Protection::Full,
            Protection::Abft,
        ] {
            let r = reg(p);
            let len_sum: usize = (0..r.n_strata()).map(|s| r.stratum_len(s)).sum();
            assert_eq!(len_sum, r.n_entries(), "{p:?}: strata must partition");
            let w_sum: f64 = (0..r.n_strata()).map(|s| r.stratum_weight(s)).sum();
            assert!(
                (w_sum - r.total_weight()).abs() < 1e-9 * r.total_weight(),
                "{p:?}: stratum weights must sum to the population weight"
            );
            let share_sum: f64 = (0..r.n_strata()).map(|s| r.stratum_share(s)).sum();
            assert!((share_sum - 1.0).abs() < 1e-12, "{p:?}");
            // Every entry's module maps into the stratum that holds it.
            for (s, _) in STRATUM_NAMES.iter().enumerate() {
                for e in r.entries() {
                    if stratum_of_module(e.site.module()) == s {
                        assert!(r.stratum_len(s) > 0);
                    }
                }
            }
        }
    }

    #[test]
    fn rare_critical_strata_are_present_but_tiny() {
        // The motivation for stratification: regfile / scheduler / checker
        // populations exist on every build but are dwarfed by the datapath,
        // so proportional sampling starves them.
        let r = reg(Protection::Full);
        for s in [2usize, 3, 4] {
            assert!(r.stratum_len(s) > 0, "{} must be populated", STRATUM_NAMES[s]);
            assert!(r.stratum_share(s) > 0.0);
        }
        let rare: f64 = [2usize, 3, 4].iter().map(|&s| r.stratum_share(s)).sum();
        assert!(
            rare < r.stratum_share(0),
            "rare strata ({rare:.3}) must be smaller than the datapath ({:.3})",
            r.stratum_share(0)
        );
    }

    #[test]
    fn stratified_sampling_stays_in_stratum_and_is_deterministic() {
        let r = reg(Protection::Full);
        for s in 0..r.n_strata() {
            if r.stratum_len(s) == 0 {
                continue;
            }
            let mut rng = Xoshiro256::new(11 + s as u64);
            for _ in 0..2_000 {
                let p = r.sample_plan_in_stratum(400, s, &mut rng).unwrap();
                assert_eq!(
                    stratum_of_module(p.site.module()),
                    s,
                    "draw must stay inside stratum {}",
                    STRATUM_NAMES[s]
                );
                assert!(p.cycle >= 1 && p.cycle <= 400);
                let e = r.entries().iter().find(|e| e.site == p.site).unwrap();
                assert!(p.bit < e.bits);
            }
            // Same seed, same draws.
            let mut r1 = Xoshiro256::new(77);
            let mut r2 = Xoshiro256::new(77);
            assert_eq!(
                r.sample_plan_in_stratum(300, s, &mut r1),
                r.sample_plan_in_stratum(300, s, &mut r2)
            );
        }
    }

    #[test]
    fn stratified_multi_plans_cover_all_models() {
        let r = reg(Protection::Abft);
        let mut out = Vec::new();
        for model in [
            FaultModel::Independent,
            FaultModel::Burst,
            FaultModel::SiteBurst,
        ] {
            for s in 0..r.n_strata() {
                let mut rng = Xoshiro256::new(5);
                r.sample_plans_in_stratum_into(200, 3, model, s, &mut rng, &mut out);
                if r.stratum_len(s) == 0 {
                    assert!(out.is_empty(), "empty stratum yields no plans");
                    continue;
                }
                assert!(!out.is_empty() && out.len() <= 3, "{model:?}/{s}");
                // The in-stratum site draw: every plan for Independent and
                // Burst; the anchor for SiteBurst (physical neighbours may
                // spill into the adjacent stratum).
                match model {
                    FaultModel::SiteBurst => {
                        assert_eq!(stratum_of_module(out[0].site.module()), s);
                    }
                    _ => {
                        for p in &out {
                            assert_eq!(stratum_of_module(p.site.module()), s);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn in_stratum_sampling_tracks_weights_within_the_stratum() {
        // Within the datapath stratum the CE-array share of in-stratum
        // draws must match its weight share, as for the global sampler.
        let r = reg(Protection::Baseline);
        let stratum = 0usize;
        let ce_weight: f64 = r
            .entries()
            .iter()
            .filter(|e| {
                e.site.module() == Module::CeArray
                    && stratum_of_module(e.site.module()) == stratum
            })
            .map(|e| e.weight)
            .sum();
        let expect = ce_weight / r.stratum_weight(stratum);
        let mut rng = Xoshiro256::new(123);
        let n = 100_000;
        let mut hits = 0u64;
        for _ in 0..n {
            let p = r.sample_plan_in_stratum(100, stratum, &mut rng).unwrap();
            if p.site.module() == Module::CeArray {
                hits += 1;
            }
        }
        let got = hits as f64 / n as f64;
        assert!(
            (got - expect).abs() < 0.01,
            "in-stratum CE share {got:.3} vs expected {expect:.3}"
        );
    }
}
