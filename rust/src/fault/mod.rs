//! Fault-injection primitives.
//!
//! The paper's campaign injects **single transient faults into
//! combinational nets** of the accelerator while it executes a GEMM, then
//! classifies the run (§4.2). The simulator mirrors that with a fault
//! *plan* — one `(site, bit, cycle)` triple per run — threaded through the
//! model as a [`FaultCtx`]:
//!
//! * **Transient (SET)** sites are combinational values: the model calls
//!   [`FaultCtx::fp16`] / [`FaultCtx::u32`] / [`FaultCtx::flag`] at the
//!   architectural point where the value is produced in a given cycle. If
//!   the planned site is not exercised in the planned cycle the fault is
//!   *masked* — exactly like a SET on an idle net.
//! * **State-upset (SEU)** sites are storage bits (buffers, accumulators,
//!   FSM state, configuration registers). The injector flips the stored
//!   bit at the start of the planned cycle via
//!   [`crate::redmule::RedMule::apply_seu`]; the flip persists until the
//!   hardware overwrites it, again matching a latched SET / SEU.
//!
//! Site identity is a dense packed [`SiteId`] so the hot path compares one
//! `u32`. The population of sites for a given configuration — with
//! area-derived sampling weights — is enumerated in [`registry`].

pub mod registry;
pub mod site;

pub use registry::{FaultRegistry, SiteEntry};
pub use site::{FaultKind, Module, SiteId};

use crate::fp::Fp16;

/// One planned fault: flip `bit` of `site` at `cycle`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    pub cycle: u64,
    pub site: SiteId,
    pub bit: u8,
    pub kind: FaultKind,
}

/// Per-run fault context threaded through the simulator.
///
/// Also records whether the planned fault was actually *applied* (the site
/// was exercised at the planned cycle), which the campaign uses to report
/// masking statistics.
#[derive(Debug, Default)]
pub struct FaultCtx {
    plan: Option<FaultPlan>,
    pub cycle: u64,
    pub applied: bool,
}

impl FaultCtx {
    pub fn clean() -> Self {
        Self::default()
    }

    pub fn with_plan(plan: FaultPlan) -> Self {
        Self {
            plan: Some(plan),
            cycle: 0,
            applied: false,
        }
    }

    pub fn plan(&self) -> Option<FaultPlan> {
        self.plan
    }

    /// Advance to the next cycle (called once per [`RedMule::step`]).
    #[inline]
    pub fn set_cycle(&mut self, cycle: u64) {
        self.cycle = cycle;
    }

    #[inline]
    fn hit(&mut self, site: SiteId) -> Option<u8> {
        match self.plan {
            Some(p) if p.kind == FaultKind::Transient && p.cycle == self.cycle && p.site == site => {
                self.applied = true;
                Some(p.bit)
            }
            _ => None,
        }
    }

    /// Pass a 16-bit datum (FP16) through a potential fault site.
    #[inline]
    pub fn fp16(&mut self, site: SiteId, v: Fp16) -> Fp16 {
        match self.hit(site) {
            Some(b) => Fp16::from_bits(v.to_bits() ^ (1 << (b & 15))),
            None => v,
        }
    }

    /// Pass a 32-bit word (address, config, counter) through a fault site.
    #[inline]
    pub fn u32(&mut self, site: SiteId, v: u32) -> u32 {
        match self.hit(site) {
            Some(b) => v ^ (1 << (b & 31)),
            None => v,
        }
    }

    /// Pass a 64-bit codeword through a fault site (bit taken mod 39 by
    /// the caller's width; we keep mod 64 here and let the registry bound
    /// the sampled bit).
    #[inline]
    pub fn u64(&mut self, site: SiteId, v: u64) -> u64 {
        match self.hit(site) {
            Some(b) => v ^ (1 << (b & 63)),
            None => v,
        }
    }

    /// Pass a single-bit control signal through a fault site.
    #[inline]
    pub fn flag(&mut self, site: SiteId, v: bool) -> bool {
        match self.hit(site) {
            Some(_) => !v,
            None => v,
        }
    }

    /// True if an SEU is planned for `cycle` (the top level applies it).
    #[inline]
    pub fn seu_due(&self, cycle: u64) -> Option<FaultPlan> {
        match self.plan {
            Some(p) if p.kind == FaultKind::StateUpset && p.cycle == cycle => Some(p),
            _ => None,
        }
    }

    /// Mark that a planned SEU was actually applied to live state.
    #[inline]
    pub fn mark_applied(&mut self) {
        self.applied = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::site::{Module, SiteId};

    #[test]
    fn transient_fires_only_on_matching_cycle_and_site() {
        let site = SiteId::new(Module::CeArray, 3, 7);
        let other = SiteId::new(Module::CeArray, 3, 8);
        let plan = FaultPlan {
            cycle: 5,
            site,
            bit: 2,
            kind: FaultKind::Transient,
        };
        let mut ctx = FaultCtx::with_plan(plan);
        ctx.set_cycle(4);
        assert_eq!(ctx.fp16(site, Fp16::ONE).to_bits(), Fp16::ONE.to_bits());
        ctx.set_cycle(5);
        assert_eq!(ctx.fp16(other, Fp16::ONE).to_bits(), Fp16::ONE.to_bits());
        assert!(!ctx.applied);
        let v = ctx.fp16(site, Fp16::ONE);
        assert_eq!(v.to_bits(), Fp16::ONE.to_bits() ^ 0b100);
        assert!(ctx.applied);
    }

    #[test]
    fn seu_is_reported_at_cycle_not_applied_inline() {
        let site = SiteId::new(Module::Accumulator, 0, 0);
        let plan = FaultPlan {
            cycle: 9,
            site,
            bit: 0,
            kind: FaultKind::StateUpset,
        };
        let mut ctx = FaultCtx::with_plan(plan);
        ctx.set_cycle(9);
        // Inline hooks ignore SEU plans...
        assert_eq!(ctx.u32(site, 42), 42);
        // ...but the top level sees it pending at cycle 9.
        assert!(ctx.seu_due(9).is_some());
        assert!(ctx.seu_due(8).is_none());
    }

    #[test]
    fn clean_ctx_never_corrupts() {
        let mut ctx = FaultCtx::clean();
        for c in 0..100 {
            ctx.set_cycle(c);
            let s = SiteId::new(Module::StreamerX, 0, c as u16);
            assert_eq!(ctx.u32(s, 0xABCD), 0xABCD);
            assert!(ctx.flag(s, true));
        }
        assert!(!ctx.applied);
    }
}
