//! Fault-injection primitives.
//!
//! The paper's campaign injects **single transient faults into
//! combinational nets** of the accelerator while it executes a GEMM, then
//! classifies the run (§4.2). The simulator mirrors that with fault
//! *plans* — `(site, bit, cycle)` triples — threaded through the model as
//! a [`FaultCtx`]:
//!
//! * **Transient (SET)** sites are combinational values: the model calls
//!   [`FaultCtx::fp16`] / [`FaultCtx::u8`] / [`FaultCtx::u32`] /
//!   [`FaultCtx::flag`] at the
//!   architectural point where the value is produced in a given cycle. If
//!   the planned site is not exercised in the planned cycle the fault is
//!   *masked* — exactly like a SET on an idle net.
//! * **State-upset (SEU)** sites are storage bits (buffers, accumulators,
//!   FSM state, configuration registers). The injector flips the stored
//!   bit at the start of the planned cycle via
//!   [`crate::redmule::RedMule::apply_seu`]; the flip persists until the
//!   hardware overwrites it, again matching a latched SET / SEU.
//!
//! One context carries **one or more** plans: the paper's Table-1 campaign
//! uses exactly one per run, while the sweep engine
//! ([`crate::campaign::sweep`]) injects N ≥ 1 per run — independent SEUs
//! or a correlated multi-bit burst (see
//! [`registry::FaultRegistry::sample_plans`]). Plans on the same site and
//! cycle compose by XOR, like simultaneous strikes on neighbouring nets.
//!
//! Site identity is a dense packed [`SiteId`] so the hot path compares one
//! `u32`. The population of sites for a given configuration — with
//! area-derived sampling weights — is enumerated in [`registry`].

pub mod registry;
pub mod site;

pub use registry::{stratum_of_module, FaultModel, FaultRegistry, SiteEntry, N_STRATA, STRATUM_NAMES};
pub use site::{FaultKind, Module, SiteId};

use crate::fp::Fp16;

/// One planned fault: flip `bit` of `site` at `cycle`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    pub cycle: u64,
    pub site: SiteId,
    pub bit: u8,
    pub kind: FaultKind,
}

/// Hard cap on plans per run (the applied-set is tracked in a `u64` mask).
pub const MAX_PLANS_PER_RUN: usize = 64;

/// Earliest planned fault cycle (`None` for a clean plan list). Execution
/// before this cycle is bit-identical to the fault-free reference — the
/// campaign's fast-forward engine keys its checkpoint selection on it.
pub fn first_fault_cycle(plans: &[FaultPlan]) -> Option<u64> {
    plans.iter().map(|p| p.cycle).min()
}

/// Latest planned fault cycle (`None` for a clean plan list). Once the
/// simulated cycle is past it no plan can fire any more, so state-digest
/// convergence checks against the reference trace become meaningful.
pub fn last_fault_cycle(plans: &[FaultPlan]) -> Option<u64> {
    plans.iter().map(|p| p.cycle).max()
}

/// The cycle-accurate *window* a plan list needs under the two-level
/// engine: `[first - settle, min(last + settle, horizon)]`, saturating at
/// 0 on the left. `settle` covers architectural settling — how long a
/// strike can keep propagating through pipeline registers before the
/// state either re-converges with the reference or visibly diverges
/// (the executor derives it from the accelerator's pipeline depth).
/// Overlapping per-plan windows from a multi-fault run are merged into
/// this single span: the plans are already sorted into one context, so
/// the union of `[cycle_i - settle, cycle_i + settle]` is covered by the
/// hull. `None` for an empty plan list (no window — the whole run is
/// fault-free and purely functional).
pub fn plan_window(plans: &[FaultPlan], settle: u64, horizon: u64) -> Option<(u64, u64)> {
    let first = first_fault_cycle(plans)?;
    let last = last_fault_cycle(plans)?;
    Some((first.saturating_sub(settle), (last + settle).min(horizon)))
}

/// Per-run fault context threaded through the simulator.
///
/// Also records which planned faults were actually *applied* (the site
/// was exercised at the planned cycle), which the campaign uses to report
/// masking statistics.
#[derive(Debug, Default)]
pub struct FaultCtx {
    plans: Vec<FaultPlan>,
    /// Bitmask over `plans` of the faults that have landed so far.
    applied_mask: u64,
    pub cycle: u64,
    /// True if any planned fault hit live state / an exercised net.
    pub applied: bool,
}

impl FaultCtx {
    pub fn clean() -> Self {
        Self::default()
    }

    pub fn with_plan(plan: FaultPlan) -> Self {
        Self::with_plans(vec![plan])
    }

    /// A context carrying several plans (multi-fault runs).
    pub fn with_plans(plans: Vec<FaultPlan>) -> Self {
        assert!(
            plans.len() <= MAX_PLANS_PER_RUN,
            "at most {MAX_PLANS_PER_RUN} faults per run"
        );
        Self {
            plans,
            applied_mask: 0,
            cycle: 0,
            applied: false,
        }
    }

    /// Re-arm this context with a new plan list, reusing the internal
    /// plan buffer: the campaign hot path resets one worker-local
    /// context per injection instead of allocating a fresh `Vec` each
    /// time (see `System::run_staged_with_faults_scratch`). Equivalent
    /// to `*self = FaultCtx::with_plans(plans.to_vec())` without the
    /// allocation.
    pub fn reset_with_plans(&mut self, plans: &[FaultPlan]) {
        assert!(
            plans.len() <= MAX_PLANS_PER_RUN,
            "at most {MAX_PLANS_PER_RUN} faults per run"
        );
        self.plans.clear();
        self.plans.extend_from_slice(plans);
        self.applied_mask = 0;
        self.cycle = 0;
        self.applied = false;
    }

    pub fn plans(&self) -> &[FaultPlan] {
        &self.plans
    }

    pub fn n_plans(&self) -> usize {
        self.plans.len()
    }

    /// How many of the planned faults have architecturally landed.
    pub fn applied_faults(&self) -> u32 {
        self.applied_mask.count_ones()
    }

    /// Advance to the next cycle (called once per [`crate::redmule::RedMule::step`]).
    #[inline]
    pub fn set_cycle(&mut self, cycle: u64) {
        self.cycle = cycle;
    }

    /// XOR mask of every transient plan that strikes `site` this cycle,
    /// with each plan's bit reduced by `width_mask` (15/31/63 for the
    /// 16/32/64-bit hooks, 0 for single-bit nets — where the XOR fold
    /// gives strike *parity*). Marks matching plans applied.
    #[inline]
    fn xor_mask(&mut self, site: SiteId, width_mask: u8) -> u64 {
        let mut m = 0u64;
        for i in 0..self.plans.len() {
            let p = self.plans[i];
            if p.kind == FaultKind::Transient && p.cycle == self.cycle && p.site == site {
                m ^= 1u64 << (p.bit & width_mask);
                self.applied_mask |= 1 << i;
                self.applied = true;
            }
        }
        m
    }

    /// Pass a 16-bit datum (FP16) through a potential fault site.
    #[inline]
    pub fn fp16(&mut self, site: SiteId, v: Fp16) -> Fp16 {
        if self.plans.is_empty() {
            return v;
        }
        let m = self.xor_mask(site, 15);
        if m == 0 {
            v
        } else {
            Fp16::from_bits(v.to_bits() ^ m as u16)
        }
    }

    /// Pass an 8-bit code (the cast units' FP8 code path) through a
    /// potential fault site.
    #[inline]
    pub fn u8(&mut self, site: SiteId, v: u8) -> u8 {
        if self.plans.is_empty() {
            return v;
        }
        v ^ self.xor_mask(site, 7) as u8
    }

    /// Pass a 32-bit word (address, config, counter) through a fault site.
    #[inline]
    pub fn u32(&mut self, site: SiteId, v: u32) -> u32 {
        if self.plans.is_empty() {
            return v;
        }
        v ^ self.xor_mask(site, 31) as u32
    }

    /// Pass a 64-bit codeword through a fault site (bit taken mod 39 by
    /// the caller's width; we keep mod 64 here and let the registry bound
    /// the sampled bit).
    #[inline]
    pub fn u64(&mut self, site: SiteId, v: u64) -> u64 {
        if self.plans.is_empty() {
            return v;
        }
        v ^ self.xor_mask(site, 63)
    }

    /// Pass a single-bit control signal through a fault site. An even
    /// number of simultaneous strikes cancels (XOR parity).
    #[inline]
    pub fn flag(&mut self, site: SiteId, v: bool) -> bool {
        if self.plans.is_empty() {
            return v;
        }
        if self.xor_mask(site, 0) != 0 {
            !v
        } else {
            v
        }
    }

    /// The `i`-th plan, if it is an SEU due at `cycle` (the top level
    /// applies it). Iterate `0..n_plans()` so multiple SEUs landing on
    /// the same cycle are all applied.
    #[inline]
    pub fn seu_due_at(&self, i: usize, cycle: u64) -> Option<FaultPlan> {
        match self.plans.get(i) {
            Some(&p) if p.kind == FaultKind::StateUpset && p.cycle == cycle => Some(p),
            _ => None,
        }
    }

    /// Mark that the `i`-th planned SEU was actually applied to live state.
    #[inline]
    pub fn mark_applied_at(&mut self, i: usize) {
        self.applied_mask |= 1 << (i % MAX_PLANS_PER_RUN);
        self.applied = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::site::{Module, SiteId};

    #[test]
    fn transient_fires_only_on_matching_cycle_and_site() {
        let site = SiteId::new(Module::CeArray, 3, 7);
        let other = SiteId::new(Module::CeArray, 3, 8);
        let plan = FaultPlan {
            cycle: 5,
            site,
            bit: 2,
            kind: FaultKind::Transient,
        };
        let mut ctx = FaultCtx::with_plan(plan);
        ctx.set_cycle(4);
        assert_eq!(ctx.fp16(site, Fp16::ONE).to_bits(), Fp16::ONE.to_bits());
        ctx.set_cycle(5);
        assert_eq!(ctx.fp16(other, Fp16::ONE).to_bits(), Fp16::ONE.to_bits());
        assert!(!ctx.applied);
        let v = ctx.fp16(site, Fp16::ONE);
        assert_eq!(v.to_bits(), Fp16::ONE.to_bits() ^ 0b100);
        assert!(ctx.applied);
        assert_eq!(ctx.applied_faults(), 1);
    }

    #[test]
    fn seu_is_reported_at_cycle_not_applied_inline() {
        let site = SiteId::new(Module::Accumulator, 0, 0);
        let plan = FaultPlan {
            cycle: 9,
            site,
            bit: 0,
            kind: FaultKind::StateUpset,
        };
        let mut ctx = FaultCtx::with_plan(plan);
        ctx.set_cycle(9);
        // Inline hooks ignore SEU plans...
        assert_eq!(ctx.u32(site, 42), 42);
        // ...but the top level sees it pending at cycle 9.
        assert!(ctx.seu_due_at(0, 9).is_some());
        assert!(ctx.seu_due_at(0, 8).is_none());
        assert!(ctx.seu_due_at(1, 9).is_none(), "only one plan armed");
        ctx.mark_applied_at(0);
        assert_eq!(ctx.applied_faults(), 1);
    }

    #[test]
    fn clean_ctx_never_corrupts() {
        let mut ctx = FaultCtx::clean();
        for c in 0..100 {
            ctx.set_cycle(c);
            let s = SiteId::new(Module::StreamerX, 0, c as u16);
            assert_eq!(ctx.u32(s, 0xABCD), 0xABCD);
            assert!(ctx.flag(s, true));
        }
        assert!(!ctx.applied);
        assert_eq!(ctx.applied_faults(), 0);
    }

    #[test]
    fn multiple_plans_fire_independently_and_are_counted() {
        let s1 = SiteId::new(Module::CeArray, 0, 1);
        let s2 = SiteId::new(Module::CeArray, 0, 2);
        let p1 = FaultPlan {
            cycle: 3,
            site: s1,
            bit: 0,
            kind: FaultKind::Transient,
        };
        let p2 = FaultPlan {
            cycle: 7,
            site: s2,
            bit: 5,
            kind: FaultKind::Transient,
        };
        let mut ctx = FaultCtx::with_plans(vec![p1, p2]);
        ctx.set_cycle(3);
        assert_eq!(ctx.u32(s1, 0), 1);
        assert_eq!(ctx.u32(s2, 0), 0, "second plan waits for its cycle");
        assert_eq!(ctx.applied_faults(), 1);
        ctx.set_cycle(7);
        assert_eq!(ctx.u32(s2, 0), 1 << 5);
        assert_eq!(ctx.applied_faults(), 2);
        // Re-striking an already-applied plan does not double-count.
        assert_eq!(ctx.u32(s2, 0), 1 << 5);
        assert_eq!(ctx.applied_faults(), 2);
    }

    #[test]
    fn reset_with_plans_equals_a_fresh_context() {
        let site = SiteId::new(Module::CeArray, 1, 4);
        let p1 = FaultPlan {
            cycle: 3,
            site,
            bit: 2,
            kind: FaultKind::Transient,
        };
        let p2 = FaultPlan {
            cycle: 8,
            site,
            bit: 1,
            kind: FaultKind::Transient,
        };
        // Dirty the reusable context thoroughly, then re-arm it.
        let mut reused = FaultCtx::with_plans(vec![p1, p2]);
        reused.set_cycle(3);
        let _ = reused.u32(site, 0);
        assert!(reused.applied);
        reused.reset_with_plans(std::slice::from_ref(&p2));
        let mut fresh = FaultCtx::with_plan(p2);
        assert_eq!(reused.plans(), fresh.plans());
        assert_eq!(reused.applied_faults(), 0);
        assert!(!reused.applied);
        assert_eq!(reused.cycle, 0);
        for cycle in 0..12 {
            reused.set_cycle(cycle);
            fresh.set_cycle(cycle);
            assert_eq!(reused.u32(site, 0xA5), fresh.u32(site, 0xA5), "cycle {cycle}");
        }
        assert_eq!(reused.applied_faults(), fresh.applied_faults());
        // Re-arming to empty behaves like `FaultCtx::clean()`.
        reused.reset_with_plans(&[]);
        assert_eq!(reused.n_plans(), 0);
        reused.set_cycle(8);
        assert_eq!(reused.u32(site, 1), 1);
        assert!(!reused.applied);
    }

    #[test]
    fn fault_cycle_ordering_helpers() {
        let site = SiteId::new(Module::CeArray, 0, 0);
        let mk = |cycle| FaultPlan {
            cycle,
            site,
            bit: 0,
            kind: FaultKind::Transient,
        };
        assert_eq!(first_fault_cycle(&[]), None);
        assert_eq!(last_fault_cycle(&[]), None);
        assert_eq!(first_fault_cycle(&[mk(9)]), Some(9));
        assert_eq!(last_fault_cycle(&[mk(9)]), Some(9));
        let plans = [mk(40), mk(3), mk(17)];
        assert_eq!(first_fault_cycle(&plans), Some(3));
        assert_eq!(last_fault_cycle(&plans), Some(40));
    }

    #[test]
    fn plan_window_rails() {
        let site = SiteId::new(Module::CeArray, 0, 0);
        let mk = |cycle| FaultPlan {
            cycle,
            site,
            bit: 0,
            kind: FaultKind::Transient,
        };
        // Empty plan list: no window at all.
        assert_eq!(plan_window(&[], 10, 100), None);
        // Interior plan: symmetric settling on both sides.
        assert_eq!(plan_window(&[mk(50)], 10, 100), Some((40, 60)));
        // Zero settle: the window degenerates to the strike cycle itself.
        assert_eq!(plan_window(&[mk(50)], 0, 100), Some((50, 50)));
        // Left edge saturates at 0 instead of underflowing.
        assert_eq!(plan_window(&[mk(3)], 10, 100), Some((0, 13)));
        // Right edge clamps at the horizon (window ≥ horizon case: the
        // whole tail is cycle-accurate, never past the recorded trace).
        assert_eq!(plan_window(&[mk(95)], 10, 100), Some((85, 100)));
        assert_eq!(plan_window(&[mk(5)], 1000, 100), Some((0, 100)));
        // Multi-fault plans: overlapping per-plan windows merge into the
        // hull of the earliest and latest strikes.
        assert_eq!(plan_window(&[mk(40), mk(3), mk(17)], 5, 100), Some((0, 45)));
        assert_eq!(plan_window(&[mk(30), mk(35)], 10, 100), Some((20, 45)));
    }

    #[test]
    fn simultaneous_strikes_on_one_site_compose_by_xor() {
        let site = SiteId::new(Module::WBuf, 0, 0);
        let mk = |bit| FaultPlan {
            cycle: 2,
            site,
            bit,
            kind: FaultKind::Transient,
        };
        // Distinct bits: both flips land.
        let mut ctx = FaultCtx::with_plans(vec![mk(1), mk(4)]);
        ctx.set_cycle(2);
        assert_eq!(ctx.u32(site, 0), (1 << 1) | (1 << 4));
        assert_eq!(ctx.applied_faults(), 2);
        // The same bit twice: the flips cancel, but both strikes landed.
        let mut ctx = FaultCtx::with_plans(vec![mk(6), mk(6)]);
        ctx.set_cycle(2);
        assert_eq!(ctx.u32(site, 0), 0);
        assert_eq!(ctx.applied_faults(), 2);
        // Single-bit net: even parity cancels, odd flips.
        let mut ctx = FaultCtx::with_plans(vec![mk(0), mk(0)]);
        ctx.set_cycle(2);
        assert!(ctx.flag(site, true), "two strikes cancel on a 1-bit net");
        let mut ctx = FaultCtx::with_plans(vec![mk(0), mk(0), mk(0)]);
        ctx.set_cycle(2);
        assert!(!ctx.flag(site, true), "three strikes flip");
    }
}
