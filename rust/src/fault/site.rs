//! Fault-site identity: which architectural location a fault targets.

/// The hardware module a site belongs to. Mirrors the module decomposition
/// of the RTL (Figure 1 of the paper) and keys the area model's weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum Module {
    /// Configuration register file (incl. shadowed context).
    RegFile = 0,
    /// X-operand streamer (address generation + request/response path).
    StreamerX = 1,
    /// W-operand streamer.
    StreamerW = 2,
    /// Y-operand streamer.
    StreamerY = 3,
    /// Z-result streamer (store path).
    StreamerZ = 4,
    /// X operand buffer (per-row registers).
    XBuf = 5,
    /// W broadcast registers (+ parity bits in FT configs).
    WBuf = 6,
    /// CE array: FMA pipeline registers and result nets.
    CeArray = 7,
    /// Per-row accumulator registers (output-stationary storage).
    Accumulator = 8,
    /// Scheduler FSM (loop counters, phase state).
    SchedFsm = 9,
    /// Top-level control FSM.
    CtrlFsm = 10,
    /// Output checkers + TCDM write filter (FT).
    Checker = 11,
    /// Reduced-width replica streamers (FT-full).
    StreamerReplica = 12,
    /// Replica scheduler/control FSMs (FT-full).
    FsmReplica = 13,
    /// Register-file parity checker (FT-full).
    RegParity = 14,
    /// Fault-status registers + interrupt logic.
    FaultUnit = 15,
}

impl Module {
    pub const ALL: [Module; 16] = [
        Module::RegFile,
        Module::StreamerX,
        Module::StreamerW,
        Module::StreamerY,
        Module::StreamerZ,
        Module::XBuf,
        Module::WBuf,
        Module::CeArray,
        Module::Accumulator,
        Module::SchedFsm,
        Module::CtrlFsm,
        Module::Checker,
        Module::StreamerReplica,
        Module::FsmReplica,
        Module::RegParity,
        Module::FaultUnit,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Module::RegFile => "regfile",
            Module::StreamerX => "streamer_x",
            Module::StreamerW => "streamer_w",
            Module::StreamerY => "streamer_y",
            Module::StreamerZ => "streamer_z",
            Module::XBuf => "xbuf",
            Module::WBuf => "wbuf",
            Module::CeArray => "ce_array",
            Module::Accumulator => "accumulator",
            Module::SchedFsm => "sched_fsm",
            Module::CtrlFsm => "ctrl_fsm",
            Module::Checker => "checker",
            Module::StreamerReplica => "streamer_replica",
            Module::FsmReplica => "fsm_replica",
            Module::RegParity => "reg_parity",
            Module::FaultUnit => "fault_unit",
        }
    }

    #[inline]
    pub fn from_u8(v: u8) -> Option<Module> {
        Module::ALL.get(v as usize).copied()
    }
}

/// Packed site identity: `module[31:26] | unit[25:20] | index[19:0]`.
///
/// `unit` distinguishes site *classes* within a module (e.g. a streamer's
/// address register vs. its response wire); `index` addresses the instance
/// (row, row*H+col, buffer slot, ...).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SiteId(pub u32);

impl SiteId {
    #[inline]
    pub fn new(module: Module, unit: u8, index: u16) -> Self {
        debug_assert!(unit < 64);
        SiteId(((module as u32) << 26) | ((unit as u32 & 0x3F) << 20) | index as u32)
    }

    /// Like [`SiteId::new`] but with a wide (20-bit) index.
    #[inline]
    pub fn with_wide_index(module: Module, unit: u8, index: u32) -> Self {
        debug_assert!(index < (1 << 20));
        SiteId(((module as u32) << 26) | ((unit as u32 & 0x3F) << 20) | (index & 0xF_FFFF))
    }

    #[inline]
    pub fn module(self) -> Module {
        Module::from_u8((self.0 >> 26) as u8).expect("valid module tag")
    }

    #[inline]
    pub fn unit(self) -> u8 {
        ((self.0 >> 20) & 0x3F) as u8
    }

    #[inline]
    pub fn index(self) -> u32 {
        self.0 & 0xF_FFFF
    }
}

/// How the fault manifests (see module docs of [`crate::fault`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// Single-event transient on a combinational value, visible only in
    /// the planned cycle.
    Transient,
    /// Latched upset of a storage bit; persists until overwritten.
    StateUpset,
}

// ---------------------------------------------------------------------
// Unit tags per module, so call sites read declaratively.
// ---------------------------------------------------------------------

/// Streamer unit tags (same for X/W/Y/Z and replica streamers).
pub mod streamer_unit {
    /// Current address register (SEU).
    pub const ADDR_REG: u8 = 0;
    /// Issued request address net (SET).
    pub const REQ_NET: u8 = 1;
    /// Response data net, pre-decode (SET); index = beat lane.
    pub const RESP_NET: u8 = 2;
    /// Loop counter registers (SEU); index = which counter.
    pub const COUNT_REG: u8 = 3;
    /// Request-valid handshake (SET).
    pub const VALID_NET: u8 = 4;
    /// Per-consumer-row ECC-decoder output net (SET); index = row.
    pub const DEC_NET: u8 = 5;
    /// Store data net (SET); index = lane (0..16 primary copy, 16..32
    /// redundant copy, 32..48 post-checker segment).
    pub const STORE_NET: u8 = 6;
    /// Cast-in unit output code net (SET, FP8 formats only): the 8-bit
    /// FP8 code between the narrowing stage and the widening stage of the
    /// fetch-path cast unit; index = consumer row (X/Y) or CE column (W).
    pub const CASTIN_NET: u8 = 7;
    /// Cast-in unit code-holding register (SEU, FP8 formats only): the
    /// 8-bit register latching the code between cast pipeline stages. One
    /// per stream; rewritten every beat, so an upset corrupts the next
    /// value cast through the stream.
    pub const CASTIN_REG: u8 = 8;
    /// Cast-out unit output code net (SET, FP8 formats only, `StreamerZ`):
    /// the 8-bit code produced by the store-path narrowing stage before it
    /// is widened back onto the FP16 carrier; index = store lane.
    pub const CASTOUT_NET: u8 = 9;
    /// Cast-out unit code-holding register (SEU, FP8 formats only,
    /// `StreamerZ`); same single-beat semantics as [`CASTIN_REG`].
    pub const CASTOUT_REG: u8 = 10;
}

/// CE-array unit tags.
pub mod ce_unit {
    /// Pipeline stage register of a CE (SEU); index = (row*H + col)*P + stage.
    pub const PIPE_REG: u8 = 0;
    /// FMA result net of a CE (SET); index = row*H + col.
    pub const FMA_NET: u8 = 1;
    /// X operand net into a CE (SET); index = row*H + col.
    pub const X_NET: u8 = 2;
    /// W broadcast wire into a CE column, post-parity-generation (SET);
    /// index = row*H + col (each row taps the broadcast separately).
    pub const W_NET: u8 = 3;
}

/// W-buffer unit tags.
pub mod wbuf_unit {
    /// Weight value register (SEU); index = column h.
    pub const VALUE_REG: u8 = 0;
    /// Parity bit register (SEU, FT only); index = column h.
    pub const PARITY_REG: u8 = 1;
    /// Value net at ECC-decode output, *before* parity generation (SET) —
    /// the small undetectable window discussed in DESIGN.md.
    pub const PRE_PARITY_NET: u8 = 2;
}

/// Scheduler-FSM unit tags.
pub mod sched_unit {
    /// Phase/state encoding register (SEU).
    pub const STATE_REG: u8 = 0;
    /// Loop counter register (SEU); index = counter id.
    pub const COUNT_REG: u8 = 1;
    /// Control signal nets to the array (SET); index = row.
    pub const CTRL_NET: u8 = 2;
}

/// Control-FSM unit tags.
pub mod ctrl_unit {
    /// State encoding register (SEU).
    pub const STATE_REG: u8 = 0;
    /// Start/done handshake nets (SET).
    pub const HANDSHAKE_NET: u8 = 1;
}

/// Register-file unit tags.
pub mod regfile_unit {
    /// Configuration word (SEU); index = ctx*WORDS + word.
    pub const WORD: u8 = 0;
    /// Parity bit (SEU, FT-full); index = ctx*WORDS + word.
    pub const PARITY: u8 = 1;
}

/// Checker unit tags.
pub mod checker_unit {
    /// Z comparator result net (SET); index = row pair.
    pub const Z_CMP_NET: u8 = 0;
    /// Write-filter decision net (SET).
    pub const WFILTER_NET: u8 = 1;
    /// FSM comparator net (SET).
    pub const FSM_CMP_NET: u8 = 2;
    /// Per-CE recompute-checker comparison net (SET, [8]-style builds);
    /// index = row*H + col.
    pub const PERCE_CMP_NET: u8 = 3;
    /// ABFT checksum-unit input tap on the store path (SET, `Abft`
    /// builds); index = store lane.
    pub const ABFT_TAP_NET: u8 = 4;
    /// ABFT checksum accumulator register (SEU, `Abft` builds); index =
    /// accumulator instance (row bank first, then column bank).
    pub const ABFT_ACC_REG: u8 = 5;
    /// Online-ABFT pre-store residual tap (SET, `AbftOnline` builds);
    /// index = store lane. Taps the value presented to the store network
    /// before the commit point.
    pub const ABFT_ONLINE_TAP_NET: u8 = 6;
    /// Online-ABFT residual accumulator register (SEU, `AbftOnline`
    /// builds); index = residual instance (row bank first, then column
    /// bank).
    pub const ABFT_RES_REG: u8 = 7;
}

/// Fault-unit tags.
pub mod fault_unit {
    /// Fault status register bits (SEU).
    pub const STATUS_REG: u8 = 0;
    /// Interrupt wire (SET).
    pub const IRQ_NET: u8 = 1;
}

/// Accumulator unit tags.
pub mod accum_unit {
    /// Accumulator register (SEU); index = row*D + slot.
    pub const REG: u8 = 0;
}

/// X-buffer unit tags.
pub mod xbuf_unit {
    /// Operand register (SEU); index = row*H + col.
    pub const REG: u8 = 0;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_round_trip() {
        for m in Module::ALL {
            let s = SiteId::new(m, 5, 1234);
            assert_eq!(s.module(), m);
            assert_eq!(s.unit(), 5);
            assert_eq!(s.index(), 1234);
        }
    }

    #[test]
    fn distinct_sites_distinct_ids() {
        let a = SiteId::new(Module::CeArray, ce_unit::PIPE_REG, 0);
        let b = SiteId::new(Module::CeArray, ce_unit::FMA_NET, 0);
        let c = SiteId::new(Module::Accumulator, accum_unit::REG, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(b, c);
    }

    #[test]
    fn wide_index_bounds() {
        let s = SiteId::with_wide_index(Module::RegFile, 1, 0xF_FFFF);
        assert_eq!(s.index(), 0xF_FFFF);
        assert_eq!(s.unit(), 1);
        assert_eq!(s.module(), Module::RegFile);
    }

    #[test]
    fn module_names_unique() {
        let mut names: Vec<_> = Module::ALL.iter().map(|m| m.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), Module::ALL.len());
    }
}
