"""Layer-2 correctness: the TinyML training graph.

Checks the shapes/contract the Rust driver relies on, that the loss
actually decreases (the backward pass through six RedMulE offloads is
numerically sane in FP16), and that gradients agree with finite
differences despite the FP16 forward quantization.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params(seed=0)


def test_shapes_and_dtypes(params):
    w1, b1, w2, b2 = params
    assert w1.shape == (model.IN_DIM, model.HIDDEN)
    assert b1.shape == (model.HIDDEN,)
    assert w2.shape == (model.HIDDEN, model.CLASSES)
    assert b2.shape == (model.CLASSES,)
    x, onehot, _ = model.spiral_batch(seed=1)
    out = model.train_step(w1, b1, w2, b2, x, onehot)
    assert len(out) == 5
    nw1, nb1, nw2, nb2, loss = out
    assert nw1.shape == w1.shape and nb1.shape == b1.shape
    assert nw2.shape == w2.shape and nb2.shape == b2.shape
    assert loss.shape == ()
    assert np.isfinite(float(loss))


def test_loss_decreases_over_training(params):
    w1, b1, w2, b2 = params
    losses = []
    for step in range(60):
        x, onehot, _ = model.spiral_batch(seed=step)
        w1, b1, w2, b2, loss = model.train_step(w1, b1, w2, b2, x, onehot)
        losses.append(float(loss))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < 0.7 * first, f"loss did not decrease: {first:.3f} -> {last:.3f}"


def test_accuracy_beats_chance_after_training(params):
    w1, b1, w2, b2 = params
    for step in range(80):
        x, onehot, _ = model.spiral_batch(seed=step)
        w1, b1, w2, b2, _ = model.train_step(w1, b1, w2, b2, x, onehot)
    hits = total = 0
    for s in range(5):
        x, _, labels = model.spiral_batch(seed=10_000 + s)
        pred = np.asarray(model.predict(w1, b1, w2, b2, x))
        hits += int((pred == labels).sum())
        total += len(labels)
    acc = hits / total
    assert acc > 0.5, f"accuracy {acc:.2f} barely beats 4-way chance"


def test_gradient_direction_matches_finite_difference(params):
    """The hand-written backward must point downhill: a step along the
    returned update direction reduces the loss computed by the forward."""
    w1, b1, w2, b2 = params
    x, onehot, _ = model.spiral_batch(seed=42)

    def loss_of(w1_, b1_, w2_, b2_):
        logits, _, _ = model.forward(w1_, b1_, w2_, b2_, x)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return float(-jnp.mean(jnp.sum(onehot * logp, axis=-1)))

    before = loss_of(w1, b1, w2, b2)
    nw1, nb1, nw2, nb2, _ = model.train_step(w1, b1, w2, b2, x, onehot)
    after = loss_of(np.asarray(nw1), np.asarray(nb1), np.asarray(nw2), np.asarray(nb2))
    assert after < before, f"SGD step increased the loss: {before:.4f} -> {after:.4f}"


def test_forward_matmuls_use_fp16_semantics(params):
    """The logits must be insensitive to sub-FP16 perturbations of the
    inputs — proof that the offloaded matmuls really quantize to FP16."""
    w1, b1, w2, b2 = params
    x, _, _ = model.spiral_batch(seed=7)
    logits_a, _, _ = model.forward(w1, b1, w2, b2, x)
    # A perturbation below half-ulp of FP16 at |x|<=4 vanishes on cast.
    x_eps = (x.astype(np.float16).astype(np.float32)) + 1e-6
    logits_b, _, _ = model.forward(w1, b1, w2, b2, x_eps)
    np.testing.assert_array_equal(np.asarray(logits_a), np.asarray(logits_b))


def test_spiral_batch_is_deterministic_and_labelled():
    x1, o1, l1 = model.spiral_batch(seed=5)
    x2, o2, l2 = model.spiral_batch(seed=5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(l1, l2)
    assert o1.shape == (model.BATCH, model.CLASSES)
    np.testing.assert_array_equal(o1.argmax(axis=1), l1)
