"""Layer-1 correctness: the Pallas kernel against the numpy oracle.

The bit-exactness contract is the core correctness signal of the whole
reproduction: the Rust golden model, the cycle-level simulator and the
PJRT-executed artifact all claim to compute the *same bits* — and they all
anchor to this oracle. Hypothesis sweeps shapes and seeds.
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.redmule import redmule_gemm, redmule_gemm_redundant
from compile.kernels.ref import gemm_ref_exact, gemm_ref_f64, random_fp16


def run_kernel(x, w, y, **kw):
    z = redmule_gemm(
        x.astype(np.float32), w.astype(np.float32), y.astype(np.float32), **kw
    )
    return np.asarray(z).astype(np.float16)


def bits(a):
    return np.asarray(a, dtype=np.float16).view(np.uint16)


class TestGemmKernelExact:
    @pytest.mark.parametrize(
        "m,n,k",
        [
            (12, 16, 16),  # the paper's campaign workload
            (1, 1, 1),
            (12, 12, 12),  # exactly one tile
            (24, 16, 24),  # multi-tile, divisible
            (13, 17, 19),  # multi-tile with ragged edges
            (5, 7, 3),
            (48, 96, 96),  # perf workload
            (12, 256, 12),  # long accumulation chain (double-rounding trap)
        ],
    )
    def test_bit_exact_vs_oracle(self, m, n, k):
        x = random_fp16((m, n), seed=m * 1000 + n)
        w = random_fp16((n, k), seed=n * 1000 + k)
        y = random_fp16((m, k), seed=m * 1000 + k)
        np.testing.assert_array_equal(
            bits(run_kernel(x, w, y)), bits(gemm_ref_exact(x, w, y))
        )

    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(1, 30),
        n=st.integers(1, 40),
        k=st.integers(1, 30),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_shape_sweep(self, m, n, k, seed):
        x = random_fp16((m, n), seed=seed)
        w = random_fp16((n, k), seed=seed + 1)
        y = random_fp16((m, k), seed=seed + 2)
        np.testing.assert_array_equal(
            bits(run_kernel(x, w, y)), bits(gemm_ref_exact(x, w, y))
        )

    @settings(max_examples=20, deadline=None)
    @given(
        mag=st.sampled_from([0.001, 1.0, 64.0, 1000.0]),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_magnitude_sweep(self, mag, seed):
        """Subnormals, large magnitudes, overflow to inf — all must match
        the oracle bit for bit (inf/NaN propagation included)."""
        m, n, k = 8, 24, 8
        x = random_fp16((m, n), seed=seed, mag=mag)
        w = random_fp16((n, k), seed=seed + 1, mag=mag)
        y = random_fp16((m, k), seed=seed + 2, mag=mag)
        np.testing.assert_array_equal(
            bits(run_kernel(x, w, y)), bits(gemm_ref_exact(x, w, y))
        )

    def test_special_values_propagate(self):
        x = np.zeros((2, 3), np.float16)
        w = np.zeros((3, 2), np.float16)
        y = np.zeros((2, 2), np.float16)
        x[0, 0] = np.float16(np.inf)
        w[0, 0] = np.float16(2.0)
        y[1, 1] = np.float16(-0.0)
        np.testing.assert_array_equal(
            bits(run_kernel(x, w, y)), bits(gemm_ref_exact(x, w, y))
        )

    def test_identity_weight_is_exact_passthrough(self):
        m = n = 12
        x = random_fp16((m, n), seed=3)
        w = np.eye(n, dtype=np.float16)
        y = np.zeros((m, n), np.float16)
        np.testing.assert_array_equal(bits(run_kernel(x, w, y)), bits(x))

    def test_order_sensitivity_is_real(self):
        """FP16 accumulation is not associative: the loose f64 reference
        must differ from the exact-order result on some element for a long
        chain — otherwise the bit-exact tests above prove nothing."""
        m, n, k = 8, 128, 8
        x = random_fp16((m, n), seed=11)
        w = random_fp16((n, k), seed=12)
        y = random_fp16((m, k), seed=13)
        exact = gemm_ref_exact(x, w, y)
        loose = gemm_ref_f64(x, w, y)
        assert (bits(exact) != bits(loose)).any()
        # ... yet they agree to FP16-accumulation tolerance.
        np.testing.assert_allclose(
            exact.astype(np.float64), loose.astype(np.float64), atol=0.35, rtol=0.02
        )

    def test_tile_size_does_not_change_bits(self):
        m, n, k = 24, 16, 24
        x = random_fp16((m, n), seed=21)
        w = random_fp16((n, k), seed=22)
        y = random_fp16((m, k), seed=23)
        a = run_kernel(x, w, y, tile_m=12, tile_k=12)
        b = run_kernel(x, w, y, tile_m=8, tile_k=6)
        c = run_kernel(x, w, y, tile_m=24, tile_k=24)
        np.testing.assert_array_equal(bits(a), bits(b))
        np.testing.assert_array_equal(bits(a), bits(c))


class TestRedundantKernel:
    @pytest.mark.parametrize("m,n,k", [(12, 16, 16), (13, 17, 19), (1, 1, 1)])
    def test_matches_oracle_with_zero_flag(self, m, n, k):
        x = random_fp16((m, n), seed=31)
        w = random_fp16((n, k), seed=32)
        y = random_fp16((m, k), seed=33)
        z, flag = redmule_gemm_redundant(
            x.astype(np.float32), w.astype(np.float32), y.astype(np.float32)
        )
        np.testing.assert_array_equal(
            bits(np.asarray(z).astype(np.float16)), bits(gemm_ref_exact(x, w, y))
        )
        assert float(flag) == 0.0, "clean duplicated compute must agree"

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31))
    def test_hypothesis_flag_always_zero_clean(self, seed):
        m, n, k = 12, 16, 16
        x = random_fp16((m, n), seed=seed)
        w = random_fp16((n, k), seed=seed + 1)
        y = random_fp16((m, k), seed=seed + 2)
        _, flag = redmule_gemm_redundant(
            x.astype(np.float32), w.astype(np.float32), y.astype(np.float32)
        )
        assert float(flag) == 0.0
