"""Hybrid-FP8 input path: quantizer properties and kernel composition."""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fp8 import fp8_grid, quantize_fp8, FORMATS
from compile.kernels.redmule import redmule_gemm
from compile.kernels.ref import gemm_ref_exact, random_fp16


@pytest.mark.parametrize("fmt", FORMATS)
def test_grid_points_are_fixed_points(fmt):
    g = fp8_grid(fmt)
    q = quantize_fp8(g.astype(np.float32), fmt)
    np.testing.assert_array_equal(q.astype(np.float64), g)
    qn = quantize_fp8((-g).astype(np.float32), fmt)
    np.testing.assert_array_equal(qn.astype(np.float64), -g)


@pytest.mark.parametrize("fmt", FORMATS)
def test_quantization_snaps_to_nearest_grid_point(fmt):
    g = fp8_grid(fmt)
    full = np.concatenate([-g[::-1], g])
    rng = np.random.default_rng(1)
    v = ((rng.random(4000) * 2 - 1) * 600).astype(np.float32)
    q = quantize_fp8(v, fmt).astype(np.float64)
    # Every output is on the grid...
    for qi in q:
        assert np.abs(full - qi).min() == 0.0, qi
    # ...and is among the two nearest neighbours (RTNE tie handling).
    for vi, qi in zip(v.astype(np.float64), q):
        d = np.abs(full - vi)
        nearest = np.sort(d)[:2]
        assert abs(abs(qi - vi) - nearest[0]) <= nearest[1] - nearest[0] + 1e-12


@pytest.mark.parametrize("fmt,maxv", [("e4m3", 448.0), ("e5m2", 57344.0)])
def test_saturation(fmt, maxv):
    v = np.array([1e6, -1e6, maxv * 1.01], np.float32)
    q = quantize_fp8(v, fmt)
    np.testing.assert_array_equal(q, [maxv, -maxv, maxv])


@pytest.mark.parametrize("fmt", FORMATS)
def test_monotone(fmt):
    v = np.linspace(-500, 500, 5001, dtype=np.float32)
    q = quantize_fp8(v, fmt)
    assert (np.diff(q) >= 0).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31), fmt=st.sampled_from(FORMATS))
def test_fp8_gemm_matches_oracle_on_quantized_inputs(seed, fmt):
    """The hybrid path: quantize X/W, then the FP16 GEMM — kernel and
    oracle must agree bit-for-bit (FP8 values are exact FP16 values)."""
    m, n, k = 12, 16, 16
    x = quantize_fp8(random_fp16((m, n), seed).astype(np.float32), fmt)
    w = quantize_fp8(random_fp16((n, k), seed + 1).astype(np.float32), fmt)
    y = random_fp16((m, k), seed + 2)
    z = np.asarray(redmule_gemm(x, w, y.astype(np.float32))).astype(np.float16)
    ref = gemm_ref_exact(
        x.astype(np.float16), w.astype(np.float16), y
    )
    np.testing.assert_array_equal(z.view(np.uint16), ref.view(np.uint16))


def test_fp8_values_are_exact_fp16_values():
    for fmt in FORMATS:
        g = fp8_grid(fmt)
        as16 = g.astype(np.float16).astype(np.float64)
        np.testing.assert_array_equal(as16, g, err_msg=f"{fmt} grid not FP16-exact")
