#!/usr/bin/env python3
"""CI validator for the sweep JSON schemas.

Validates one document against the schema family it claims:

* ``redmule-ft/sweep-v1``      — the legacy flat-counts grid document
* ``redmule-ft/sweep-v2``      — per-outcome {count, rate, ci_lo, ci_hi},
                                 n_injections / stopped_early per cell,
                                 a top-level ``confidence`` level and —
                                 for stratified sweeps — the per-stratum
                                 estimate table of every cell
* ``redmule-ft/bench-sweep-v1`` — the wall-clock sidecar (plus optional
                                 trace-cache hit/miss counters)

Usage:
    validate_sweep.py FILE --schema v1|v2|bench-sweep
        [--cells N] [--injections N] [--max-injections N]
        [--fault-model M] [--expect-stopped-early]

Exits non-zero with a diagnostic on the first violation.
"""

import argparse
import json
import sys

PROTECTIONS = ("baseline", "data", "full", "per-ce", "abft", "abft-online")
RECOVERIES = ("full-restart", "tile-level", "in-place-correct")
ENGINES = ("direct", "fast-forward", "two-level")
# Non-default axis values only: cells on the fp16 / mul defaults omit the
# "format" / "op" fields entirely (byte-identity of pre-existing sweeps).
FORMATS = ("fp8-e4m3", "fp8-e5m2")
OPS = ("addmax", "addmin", "mulmax", "mulmin")
OUTCOME_KEYS = ("correct_no_retry", "correct_with_retry", "incorrect", "timeout")
EPS = 1e-6


def fail(msg):
    print(f"validate_sweep: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_coords(c):
    if not {"l", "h", "p"} <= set(c["geometry"]):
        fail(f"bad geometry in {c}")
    if not {"m", "n", "k"} <= set(c["shape"]):
        fail(f"bad shape in {c}")
    if c["protection"] not in PROTECTIONS:
        fail(f"unknown protection {c['protection']}")
    # Format / op discriminants (precision & op-family axes): optional so
    # default-path documents stay byte-identical, but when present they
    # must name a known non-default value (same idiom as "engine").
    if "format" in c and c["format"] not in FORMATS:
        fail(f"unknown format {c['format']} (expected one of {FORMATS})")
    if "op" in c and c["op"] not in OPS:
        fail(f"unknown op {c['op']} (expected one of {OPS})")
    if c["faults"] < 1:
        fail(f"bad fault count in {c}")


def check_v1(d, args):
    if d["schema"] != "redmule-ft/sweep-v1":
        fail(f"schema {d['schema']} != redmule-ft/sweep-v1")
    cells = d["cells"]
    if d["total_runs"] != sum(c["total"] for c in cells):
        fail("total_runs mismatch")
    for c in cells:
        check_coords(c)
        o = c["outcomes"]
        if c["total"] != sum(o[k] for k in OUTCOME_KEYS):
            fail(f"outcome counts do not partition the cell: {c}")
        if not 0.0 <= c["rates"]["functional_error"] <= 1.0:
            fail(f"bad functional_error rate: {c}")
        if args.injections is not None and c["total"] != args.injections:
            fail(f"cell ran {c['total']} != {args.injections}")
    return cells


def check_outcome_obj(tag, o, n):
    for key in ("count", "rate", "ci_lo", "ci_hi"):
        if key not in o:
            fail(f"{tag}: missing {key}")
    if not 0 <= o["count"] <= n:
        fail(f"{tag}: count {o['count']} out of range (n={n})")
    if abs(o["rate"] - o["count"] / max(n, 1)) > 1e-4 and "weighted" not in tag:
        # Stratified cells reweight the rate; pooled ones must match.
        fail(f"{tag}: rate {o['rate']} inconsistent with count/n")
    if not (0.0 - EPS <= o["ci_lo"] <= o["ci_hi"] <= 1.0 + EPS):
        fail(f"{tag}: malformed interval [{o['ci_lo']}, {o['ci_hi']}]")
    if "upper95" in o and o["upper95"] + EPS < o["rate"]:
        fail(f"{tag}: upper95 below the point estimate")


def check_strata(tag, c, n):
    """Per-stratum estimate table of one stratified cell (PR 5)."""
    if "strata" not in c:
        fail(f"{tag}: stratified cell carries no strata block")
    strata = c["strata"]
    if not strata:
        fail(f"{tag}: empty strata block")
    if sum(s["n"] for s in strata) != n:
        fail(f"{tag}: stratum allocations do not sum to n_injections")
    share_total = 0.0
    for s in strata:
        if not s.get("name"):
            fail(f"{tag}: unnamed stratum")
        stag = f"{tag}/{s['name']}"
        if not 0.0 - EPS <= s["share"] <= 1.0 + EPS:
            fail(f"{stag}: share {s['share']} out of range")
        share_total += s["share"]
        counts = 0
        for key in OUTCOME_KEYS:
            o = s["outcomes"][key]
            check_outcome_obj(f"{stag}/{key}", o, s["n"])
            counts += o["count"]
        if counts != s["n"]:
            fail(f"{stag}: outcome counts {counts} != stratum n {s['n']}")
        fe = s["functional_error"]
        check_outcome_obj(f"{stag}/functional_error", fe, s["n"])
        expect = (
            s["outcomes"]["incorrect"]["count"] + s["outcomes"]["timeout"]["count"]
        )
        if fe["count"] != expect:
            fail(f"{stag}: functional_error count {fe['count']} != {expect}")
    if abs(share_total - 1.0) > 1e-3:
        fail(f"{tag}: stratum shares sum to {share_total}, expected 1")


def check_v2(d, args):
    if d["schema"] != "redmule-ft/sweep-v2":
        fail(f"schema {d['schema']} != redmule-ft/sweep-v2")
    if not isinstance(d["stratified"], bool):
        fail("stratified must be a bool")
    if d["precision_target"] < 0:
        fail("negative precision_target")
    if "confidence" in d and not 0.0 < d["confidence"] < 1.0:
        fail(f"confidence {d['confidence']} out of (0, 1)")
    cells = d["cells"]
    if d["total_runs"] != sum(c["n_injections"] for c in cells):
        fail("total_runs mismatch")
    cap = args.max_injections or args.injections
    for c in cells:
        check_coords(c)
        n = c["n_injections"]
        if n < 1:
            fail(f"cell ran no injections: {c}")
        if cap is not None and n > cap:
            fail(f"cell ran {n} > cap {cap}")
        if (
            args.injections is not None
            and d["precision_target"] == 0
            and n != args.injections
        ):
            fail(f"fixed-budget cell ran {n} != {args.injections}")
        if not isinstance(c["stopped_early"], bool):
            fail(f"stopped_early must be a bool: {c}")
        if c["batches"] < 1:
            fail(f"bad batch count: {c}")
        tagbase = f"{c['protection']}/{c['faults']}f"
        if c["recovery"] not in RECOVERIES:
            fail(f"{tagbase}: unknown recovery {c.get('recovery')}")
        for key in ("corrections", "band_recomputes"):
            if not isinstance(c[key], int) or c[key] < 0:
                fail(f"{tagbase}: bad {key} {c[key]}")
        if c["recovery"] != "in-place-correct" and c["corrections"] != 0:
            fail(f"{tagbase}: corrections reported without in-place recovery")
        weighted = "/weighted" if d["stratified"] else ""
        counts = 0
        for key in OUTCOME_KEYS:
            o = c["outcomes"][key]
            check_outcome_obj(f"{tagbase}/{key}{weighted}", o, n)
            counts += o["count"]
        if counts != n:
            fail(f"{tagbase}: outcome counts {counts} != n_injections {n}")
        fe = c["functional_error"]
        check_outcome_obj(f"{tagbase}/functional_error{weighted}", fe, n)
        if "upper95" not in fe:
            fail(f"{tagbase}: functional_error must carry upper95")
        expect_fe = (
            c["outcomes"]["incorrect"]["count"] + c["outcomes"]["timeout"]["count"]
        )
        if fe["count"] != expect_fe:
            fail(f"{tagbase}: functional_error count {fe['count']} != {expect_fe}")
        if d["stratified"]:
            check_strata(tagbase, c, n)
        elif "strata" in c:
            fail(f"{tagbase}: unstratified cell must not carry strata")
        if args.expect_stopped_early:
            if not c["stopped_early"]:
                fail(f"{tagbase}: expected an early stop, ran {n}")
            if cap is not None and n >= cap:
                fail(f"{tagbase}: early stop cannot use the whole cap")
    return cells


def check_bench_sweep(d, args):
    if d["schema"] != "redmule-ft/bench-sweep-v1":
        fail(f"schema {d['schema']} != redmule-ft/bench-sweep-v1")
    # Engine discriminant (two-level tentpole): optional so pre-existing
    # sidecars stay valid, but when present it must name a known engine.
    if "engine" in d and d["engine"] not in ENGINES:
        fail(f"unknown engine {d['engine']} (expected one of {ENGINES})")
    # Totals are rounded to 3 decimals / 1 decimal, so tiny smoke grids
    # can legitimately round to zero — only negatives are malformed.
    if d["wall_seconds"] < 0:
        fail("negative wall_seconds")
    if d["runs_per_sec"] < 0:
        fail("negative runs_per_sec")
    if d["total_runs"] != sum(c["n_injections"] for c in d["cells"]):
        fail("total_runs mismatch")
    for c in d["cells"]:
        check_coords(c)
        if c["n_injections"] < 1:
            fail(f"cell ran no injections: {c}")
        if c["wall_seconds"] < 0 or c["injections_per_sec"] < 0:
            fail(f"negative timing: {c}")
    return d["cells"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("file")
    ap.add_argument("--schema", choices=("v1", "v2", "bench-sweep"), required=True)
    ap.add_argument("--cells", type=int, default=None)
    ap.add_argument("--injections", type=int, default=None)
    ap.add_argument("--max-injections", type=int, default=None)
    ap.add_argument("--fault-model", default=None)
    ap.add_argument("--expect-format", default=None)
    ap.add_argument("--expect-op", default=None)
    ap.add_argument("--expect-stopped-early", action="store_true")
    args = ap.parse_args()

    with open(args.file) as f:
        d = json.load(f)

    if args.fault_model is not None and d.get("fault_model") != args.fault_model:
        fail(f"fault_model {d.get('fault_model')} != {args.fault_model}")

    cells = {"v1": check_v1, "v2": check_v2, "bench-sweep": check_bench_sweep}[
        args.schema
    ](d, args)

    if args.cells is not None and len(cells) != args.cells:
        fail(f"{len(cells)} cells != expected {args.cells}")

    # Single-valued format/op sweeps: every cell must carry the expected
    # discriminant (a missing field means the cell ran the default).
    if args.expect_format is not None:
        for c in cells:
            if c.get("format") != args.expect_format:
                fail(f"cell format {c.get('format')} != {args.expect_format}")
    if args.expect_op is not None:
        for c in cells:
            if c.get("op") != args.expect_op:
                fail(f"cell op {c.get('op')} != {args.expect_op}")

    print(
        f"validate_sweep: OK ({args.schema}, {len(cells)} cells, "
        f"{sum(c.get('n_injections', c.get('total', 0)) for c in cells)} runs)"
    )


if __name__ == "__main__":
    main()
