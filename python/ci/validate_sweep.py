#!/usr/bin/env python3
"""CI validator for the sweep JSON schemas.

Validates one document against the schema family it claims:

* ``redmule-ft/sweep-v1``      — the legacy flat-counts grid document
* ``redmule-ft/sweep-v2``      — per-outcome {count, rate, ci_lo, ci_hi},
                                 n_injections / stopped_early per cell,
                                 a top-level ``confidence`` level and —
                                 for stratified sweeps — the per-stratum
                                 estimate table of every cell
* ``redmule-ft/bench-sweep-v1`` — the wall-clock sidecar (plus optional
                                 trace-cache hit/miss counters)
* ``redmule-ft/mesh-campaign-v1`` — the ``mesh --json`` document: outcome
                                 counts, NoC event counters and the
                                 per-``mesh/noc-*``-stratum attribution

Usage:
    validate_sweep.py FILE --schema v1|v2|bench-sweep|mesh-campaign
        [--cells N] [--injections N] [--max-injections N]
        [--fault-model M] [--expect-stopped-early]
        [--expect-no-functional-errors] [--expect-retirement]

Exits non-zero with a diagnostic on the first violation.
"""

import argparse
import json
import sys

PROTECTIONS = ("baseline", "data", "full", "per-ce", "abft", "abft-online")
RECOVERIES = ("full-restart", "tile-level", "in-place-correct")
ENGINES = ("direct", "fast-forward", "two-level")
# Non-default axis values only: cells on the fp16 / mul defaults omit the
# "format" / "op" fields entirely (byte-identity of pre-existing sweeps).
FORMATS = ("fp8-e4m3", "fp8-e5m2")
OPS = ("addmax", "addmin", "mulmax", "mulmin")
OUTCOME_KEYS = ("correct_no_retry", "correct_with_retry", "incorrect", "timeout")
# The mesh interconnect fault domain (disjoint from the datapath strata).
NOC_STRATA = ("mesh/noc-link", "mesh/noc-router", "mesh/noc-tile")
MESH_CELL_KEYS = (
    "tiles",
    "shards",
    "retired_tiles",
    "reassigned_shards",
    "noc_applied",
    "noc_detected",
    "noc_corrected",
)
EPS = 1e-6


def fail(msg):
    print(f"validate_sweep: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_coords(c):
    if not {"l", "h", "p"} <= set(c["geometry"]):
        fail(f"bad geometry in {c}")
    if not {"m", "n", "k"} <= set(c["shape"]):
        fail(f"bad shape in {c}")
    if c["protection"] not in PROTECTIONS:
        fail(f"unknown protection {c['protection']}")
    # Format / op discriminants (precision & op-family axes): optional so
    # default-path documents stay byte-identical, but when present they
    # must name a known non-default value (same idiom as "engine").
    if "format" in c and c["format"] not in FORMATS:
        fail(f"unknown format {c['format']} (expected one of {FORMATS})")
    if "op" in c and c["op"] not in OPS:
        fail(f"unknown op {c['op']} (expected one of {OPS})")
    # Mesh tile-count discriminant: single-tile cells omit the field
    # entirely (byte-identity of pre-existing sweeps), so when present
    # it must be a genuine multi-tile count.
    if "tiles" in c and (not isinstance(c["tiles"], int) or c["tiles"] < 2):
        fail(f"bad tiles {c.get('tiles')} (single-tile cells omit the field)")
    if c["faults"] < 1:
        fail(f"bad fault count in {c}")


def check_v1(d, args):
    if d["schema"] != "redmule-ft/sweep-v1":
        fail(f"schema {d['schema']} != redmule-ft/sweep-v1")
    cells = d["cells"]
    if d["total_runs"] != sum(c["total"] for c in cells):
        fail("total_runs mismatch")
    for c in cells:
        check_coords(c)
        o = c["outcomes"]
        if c["total"] != sum(o[k] for k in OUTCOME_KEYS):
            fail(f"outcome counts do not partition the cell: {c}")
        if not 0.0 <= c["rates"]["functional_error"] <= 1.0:
            fail(f"bad functional_error rate: {c}")
        if args.injections is not None and c["total"] != args.injections:
            fail(f"cell ran {c['total']} != {args.injections}")
    return cells


def check_outcome_obj(tag, o, n):
    for key in ("count", "rate", "ci_lo", "ci_hi"):
        if key not in o:
            fail(f"{tag}: missing {key}")
    if not 0 <= o["count"] <= n:
        fail(f"{tag}: count {o['count']} out of range (n={n})")
    if abs(o["rate"] - o["count"] / max(n, 1)) > 1e-4 and "weighted" not in tag:
        # Stratified cells reweight the rate; pooled ones must match.
        fail(f"{tag}: rate {o['rate']} inconsistent with count/n")
    if not (0.0 - EPS <= o["ci_lo"] <= o["ci_hi"] <= 1.0 + EPS):
        fail(f"{tag}: malformed interval [{o['ci_lo']}, {o['ci_hi']}]")
    if "upper95" in o and o["upper95"] + EPS < o["rate"]:
        fail(f"{tag}: upper95 below the point estimate")


def check_strata(tag, c, n):
    """Per-stratum estimate table of one stratified cell (PR 5)."""
    if "strata" not in c:
        fail(f"{tag}: stratified cell carries no strata block")
    strata = c["strata"]
    if not strata:
        fail(f"{tag}: empty strata block")
    if sum(s["n"] for s in strata) != n:
        fail(f"{tag}: stratum allocations do not sum to n_injections")
    share_total = 0.0
    for s in strata:
        if not s.get("name"):
            fail(f"{tag}: unnamed stratum")
        stag = f"{tag}/{s['name']}"
        if not 0.0 - EPS <= s["share"] <= 1.0 + EPS:
            fail(f"{stag}: share {s['share']} out of range")
        share_total += s["share"]
        counts = 0
        for key in OUTCOME_KEYS:
            o = s["outcomes"][key]
            check_outcome_obj(f"{stag}/{key}", o, s["n"])
            counts += o["count"]
        if counts != s["n"]:
            fail(f"{stag}: outcome counts {counts} != stratum n {s['n']}")
        fe = s["functional_error"]
        check_outcome_obj(f"{stag}/functional_error", fe, s["n"])
        expect = (
            s["outcomes"]["incorrect"]["count"] + s["outcomes"]["timeout"]["count"]
        )
        if fe["count"] != expect:
            fail(f"{stag}: functional_error count {fe['count']} != {expect}")
    if abs(share_total - 1.0) > 1e-3:
        fail(f"{tag}: stratum shares sum to {share_total}, expected 1")


def check_v2(d, args):
    if d["schema"] != "redmule-ft/sweep-v2":
        fail(f"schema {d['schema']} != redmule-ft/sweep-v2")
    if not isinstance(d["stratified"], bool):
        fail("stratified must be a bool")
    if d["precision_target"] < 0:
        fail("negative precision_target")
    if "confidence" in d and not 0.0 < d["confidence"] < 1.0:
        fail(f"confidence {d['confidence']} out of (0, 1)")
    cells = d["cells"]
    if d["total_runs"] != sum(c["n_injections"] for c in cells):
        fail("total_runs mismatch")
    cap = args.max_injections or args.injections
    for c in cells:
        check_coords(c)
        n = c["n_injections"]
        if n < 1:
            fail(f"cell ran no injections: {c}")
        if cap is not None and n > cap:
            fail(f"cell ran {n} > cap {cap}")
        if (
            args.injections is not None
            and d["precision_target"] == 0
            and n != args.injections
        ):
            fail(f"fixed-budget cell ran {n} != {args.injections}")
        if not isinstance(c["stopped_early"], bool):
            fail(f"stopped_early must be a bool: {c}")
        if c["batches"] < 1:
            fail(f"bad batch count: {c}")
        tagbase = f"{c['protection']}/{c['faults']}f"
        if c["recovery"] not in RECOVERIES:
            fail(f"{tagbase}: unknown recovery {c.get('recovery')}")
        for key in ("corrections", "band_recomputes"):
            if not isinstance(c[key], int) or c[key] < 0:
                fail(f"{tagbase}: bad {key} {c[key]}")
        # Mesh cells legitimately report corrections with any recovery
        # policy: theirs are reduction-ABFT localizations on the NoC,
        # not in-place datapath corrections.
        if (
            c["recovery"] != "in-place-correct"
            and "mesh" not in c
            and c["corrections"] != 0
        ):
            fail(f"{tagbase}: corrections reported without in-place recovery")
        # Mesh cells (tiles axis): the NoC attribution rides in a "mesh"
        # object; a multi-tile cell without one is malformed, as is a
        # mesh block on a single-tile cell.
        if "mesh" in c:
            m = c["mesh"]
            if c.get("tiles") != m.get("tiles"):
                fail(
                    f"{tagbase}: mesh block tiles {m.get('tiles')} "
                    f"!= cell tiles {c.get('tiles')}"
                )
            for key in MESH_CELL_KEYS:
                if not isinstance(m.get(key), int) or m[key] < 0:
                    fail(f"{tagbase}: bad mesh field {key}={m.get(key)}")
        elif c.get("tiles", 1) != 1:
            fail(f"{tagbase}: multi-tile cell carries no mesh block")
        weighted = "/weighted" if d["stratified"] else ""
        counts = 0
        for key in OUTCOME_KEYS:
            o = c["outcomes"][key]
            check_outcome_obj(f"{tagbase}/{key}{weighted}", o, n)
            counts += o["count"]
        if counts != n:
            fail(f"{tagbase}: outcome counts {counts} != n_injections {n}")
        fe = c["functional_error"]
        check_outcome_obj(f"{tagbase}/functional_error{weighted}", fe, n)
        if "upper95" not in fe:
            fail(f"{tagbase}: functional_error must carry upper95")
        expect_fe = (
            c["outcomes"]["incorrect"]["count"] + c["outcomes"]["timeout"]["count"]
        )
        if fe["count"] != expect_fe:
            fail(f"{tagbase}: functional_error count {fe['count']} != {expect_fe}")
        if d["stratified"]:
            check_strata(tagbase, c, n)
        elif "strata" in c:
            fail(f"{tagbase}: unstratified cell must not carry strata")
        if args.expect_stopped_early:
            if not c["stopped_early"]:
                fail(f"{tagbase}: expected an early stop, ran {n}")
            if cap is not None and n >= cap:
                fail(f"{tagbase}: early stop cannot use the whole cap")
    return cells


def check_bench_sweep(d, args):
    if d["schema"] != "redmule-ft/bench-sweep-v1":
        fail(f"schema {d['schema']} != redmule-ft/bench-sweep-v1")
    # Engine discriminant (two-level tentpole): optional so pre-existing
    # sidecars stay valid, but when present it must name a known engine.
    if "engine" in d and d["engine"] not in ENGINES:
        fail(f"unknown engine {d['engine']} (expected one of {ENGINES})")
    # Totals are rounded to 3 decimals / 1 decimal, so tiny smoke grids
    # can legitimately round to zero — only negatives are malformed.
    if d["wall_seconds"] < 0:
        fail("negative wall_seconds")
    if d["runs_per_sec"] < 0:
        fail("negative runs_per_sec")
    if d["total_runs"] != sum(c["n_injections"] for c in d["cells"]):
        fail("total_runs mismatch")
    for c in d["cells"]:
        check_coords(c)
        if c["n_injections"] < 1:
            fail(f"cell ran no injections: {c}")
        if c["wall_seconds"] < 0 or c["injections_per_sec"] < 0:
            fail(f"negative timing: {c}")
    return d["cells"]


def check_mesh_campaign(d, args):
    if d["schema"] != "redmule-ft/mesh-campaign-v1":
        fail(f"schema {d['schema']} != redmule-ft/mesh-campaign-v1")
    if d["tiles"] < 1 or d["shards"] < 1:
        fail(f"bad mesh geometry: tiles={d['tiles']} shards={d['shards']}")
    o = d["outcomes"]
    total = sum(o[k] for k in OUTCOME_KEYS)
    if total != d["injections"]:
        fail(f"outcome counts {total} do not partition injections {d['injections']}")
    if not 0 <= d["applied_runs"] <= d["injections"]:
        fail(f"applied_runs {d['applied_runs']} out of range")
    for key, v in d["events"].items():
        if not isinstance(v, int) or v < 0:
            fail(f"bad event counter {key}={v}")
    strata = d["strata"]
    if tuple(s["name"] for s in strata) != NOC_STRATA:
        fail(f"NoC strata {[s['name'] for s in strata]} != {list(NOC_STRATA)}")
    share_total = sum(s["share"] for s in strata)
    if abs(share_total - 1.0) > 1e-3:
        fail(f"NoC stratum shares sum to {share_total}, expected 1")
    for s in strata:
        for key in ("applied", "detected", "corrected", "functional_errors"):
            if not isinstance(s[key], int) or s[key] < 0:
                fail(f"{s['name']}: bad {key} {s[key]}")
    fe = o["incorrect"] + o["timeout"]
    if args.expect_no_functional_errors and fe != 0:
        fail(f"{fe} functional errors (expected a fully absorbed campaign)")
    if args.expect_retirement:
        e = d["events"]
        if e["tiles_retired"] < 1 or e["shards_reassigned"] < 1:
            fail(
                "expected crash retirement: "
                f"tiles_retired={e['tiles_retired']} "
                f"shards_reassigned={e['shards_reassigned']}"
            )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("file")
    ap.add_argument(
        "--schema",
        choices=("v1", "v2", "bench-sweep", "mesh-campaign"),
        required=True,
    )
    ap.add_argument("--cells", type=int, default=None)
    ap.add_argument("--injections", type=int, default=None)
    ap.add_argument("--max-injections", type=int, default=None)
    ap.add_argument("--fault-model", default=None)
    ap.add_argument("--expect-format", default=None)
    ap.add_argument("--expect-op", default=None)
    ap.add_argument("--expect-stopped-early", action="store_true")
    ap.add_argument("--expect-no-functional-errors", action="store_true")
    ap.add_argument("--expect-retirement", action="store_true")
    args = ap.parse_args()

    with open(args.file) as f:
        d = json.load(f)

    if args.schema == "mesh-campaign":
        check_mesh_campaign(d, args)
        print(
            f"validate_sweep: OK (mesh-campaign, {d['tiles']} tiles, "
            f"{d['shards']} shards, {d['injections']} runs)"
        )
        return

    if args.fault_model is not None and d.get("fault_model") != args.fault_model:
        fail(f"fault_model {d.get('fault_model')} != {args.fault_model}")

    cells = {"v1": check_v1, "v2": check_v2, "bench-sweep": check_bench_sweep}[
        args.schema
    ](d, args)

    if args.cells is not None and len(cells) != args.cells:
        fail(f"{len(cells)} cells != expected {args.cells}")

    # Single-valued format/op sweeps: every cell must carry the expected
    # discriminant (a missing field means the cell ran the default).
    if args.expect_format is not None:
        for c in cells:
            if c.get("format") != args.expect_format:
                fail(f"cell format {c.get('format')} != {args.expect_format}")
    if args.expect_op is not None:
        for c in cells:
            if c.get("op") != args.expect_op:
                fail(f"cell op {c.get('op')} != {args.expect_op}")

    print(
        f"validate_sweep: OK ({args.schema}, {len(cells)} cells, "
        f"{sum(c.get('n_injections', c.get('total', 0)) for c in cells)} runs)"
    )


if __name__ == "__main__":
    main()
