#!/usr/bin/env python3
"""CI bench-trajectory gate: fail when throughput regresses too far.

Compares a freshly measured bench JSON against the committed
previous-run snapshot (``ci/bench-baselines/``). Handles both schemas:

* ``redmule-ft/bench-campaign-v1`` — gate on the aggregate fast-engine
  campaign throughput (mean of ``runs_per_sec_fast`` over the protection
  columns), and warn per column;
* ``redmule-ft/bench-sweep-v1``   — gate on the sweep's total
  ``runs_per_sec``.

A missing baseline is a *bootstrap*, not a failure: the step prints how
to commit one (download the artifact of this run) and exits 0. CI
runners are shared and noisy, so the default gate is the ISSUE's 30 %;
tune with ``--max-regress``.

Usage:
    compare_bench.py --current FILE --baseline FILE [--max-regress 0.30]
"""

import argparse
import json
import os
import sys


def metric(d):
    schema = d.get("schema")
    if schema == "redmule-ft/bench-campaign-v1":
        cols = d["columns"]
        per = {c["protection"]: c["runs_per_sec_fast"] for c in cols}
        return sum(per.values()) / len(per), per
    if schema == "redmule-ft/bench-sweep-v1":
        return d["runs_per_sec"], {"sweep": d["runs_per_sec"]}
    print(f"compare_bench: unknown schema {schema}", file=sys.stderr)
    sys.exit(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--max-regress", type=float, default=0.30)
    args = ap.parse_args()

    with open(args.current) as f:
        cur = json.load(f)

    if not os.path.exists(args.baseline):
        print(
            f"compare_bench: no committed baseline at {args.baseline} — "
            "bootstrap: download this run's bench artifact and commit it "
            "there to arm the trajectory gate. Skipping."
        )
        return

    with open(args.baseline) as f:
        base = json.load(f)

    if cur.get("schema") != base.get("schema"):
        print(
            f"compare_bench: schema mismatch (current {cur.get('schema')} vs "
            f"baseline {base.get('schema')}) — re-baseline.",
            file=sys.stderr,
        )
        sys.exit(1)

    cur_agg, cur_per = metric(cur)
    base_agg, base_per = metric(base)

    for name, b in sorted(base_per.items()):
        c = cur_per.get(name)
        if c is None:
            print(f"compare_bench: WARN column {name} missing from current run")
            continue
        delta = (c - b) / b if b > 0 else 0.0
        print(f"compare_bench: {name:<10} baseline {b:>10.1f}  current {c:>10.1f}  ({delta:+.1%})")

    if base_agg <= 0:
        print("compare_bench: degenerate baseline (<= 0), skipping gate")
        return
    ratio = cur_agg / base_agg
    print(
        f"compare_bench: aggregate baseline {base_agg:.1f} vs current {cur_agg:.1f} "
        f"({ratio - 1.0:+.1%}, gate -{args.max_regress:.0%})"
    )
    if ratio < 1.0 - args.max_regress:
        print(
            f"compare_bench: FAIL — throughput regressed more than "
            f"{args.max_regress:.0%} against the committed snapshot",
            file=sys.stderr,
        )
        sys.exit(1)
    print("compare_bench: OK")


if __name__ == "__main__":
    main()
