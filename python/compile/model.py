"""Layer-2 JAX model: TinyML training on RedMulE's numeric contract.

RedMulE's motivating workload (the RedMulE paper targets "on-chip linear
algebra and TinyML training acceleration") is small-model training where
every matrix product runs on the accelerator. This module builds exactly
that compute graph: a 2-layer MLP classifier whose **forward and backward
matmuls all go through the Layer-1 Pallas kernel** — i.e. FP16 RedMulE
semantics — while the parameter master copies and elementwise glue stay in
f32, the standard mixed-precision TinyML recipe.

The backward pass is written out by hand (pallas_call has no autodiff
rule, and the explicit form mirrors how a RedMulE-based runtime would
schedule the accelerator: six GEMM offloads per step).

Everything is shape-static so `aot.py` can lower `train_step` once and the
Rust driver (`examples/tinyml_training.rs`) can run hundreds of steps
against the same artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.redmule import redmule_gemm

# Static architecture of the example classifier.
BATCH = 32
IN_DIM = 16
HIDDEN = 32
CLASSES = 4
LEARNING_RATE = 0.1


def _fp16_vals(v):
    """Quantize a f32 tensor to FP16 values (kept on an f32 carrier) —
    what the DMA would deliver to TCDM before an offload."""
    return v.astype(jnp.float16).astype(jnp.float32)


def gemm(x, w, y):
    """One accelerator offload: Z = Y + X·W in RedMulE FP16 order.
    Operands are quantized to FP16 values first, as staging to TCDM does."""
    return redmule_gemm(_fp16_vals(x), _fp16_vals(w), _fp16_vals(y))


def init_params(seed: int = 0):
    """He-initialized f32 master parameters."""
    rng = np.random.default_rng(seed)
    w1 = (rng.standard_normal((IN_DIM, HIDDEN)) * np.sqrt(2.0 / IN_DIM)).astype(np.float32)
    b1 = np.zeros((HIDDEN,), np.float32)
    w2 = (rng.standard_normal((HIDDEN, CLASSES)) * np.sqrt(2.0 / HIDDEN)).astype(np.float32)
    b2 = np.zeros((CLASSES,), np.float32)
    return w1, b1, w2, b2


def forward(w1, b1, w2, b2, x):
    """Forward pass; returns (logits, hidden activations, pre-activation)."""
    y1 = jnp.broadcast_to(b1[None, :], (x.shape[0], HIDDEN))
    h_pre = gemm(x, w1, y1)  # offload 1
    h = jax.nn.relu(h_pre)
    y2 = jnp.broadcast_to(b2[None, :], (x.shape[0], CLASSES))
    logits = gemm(h, w2, y2)  # offload 2
    return logits, h, h_pre


def train_step(w1, b1, w2, b2, x, labels_onehot):
    """One SGD step. Returns (w1', b1', w2', b2', loss).

    Six RedMulE offloads: 2 forward + 4 backward GEMMs. The elementwise
    softmax/ReLU glue runs on the host cores in f32, as it would in the
    PULP cluster.
    """
    b = x.shape[0]
    logits, h, h_pre = forward(w1, b1, w2, b2, x)

    # Softmax cross-entropy in f32 (host-side glue).
    logits_f32 = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits_f32, axis=-1)
    loss = -jnp.mean(jnp.sum(labels_onehot * logp, axis=-1))

    # Backward.
    dlogits = (jax.nn.softmax(logits_f32, axis=-1) - labels_onehot) / b
    zeros_hc = jnp.zeros((HIDDEN, CLASSES), jnp.float32)
    dw2 = gemm(h.T, dlogits, zeros_hc)  # offload 3
    db2 = jnp.sum(dlogits, axis=0)
    zeros_bh = jnp.zeros((b, HIDDEN), jnp.float32)
    dh = gemm(dlogits, w2.T, zeros_bh)  # offload 4
    dh = dh * (h_pre > 0).astype(jnp.float32)
    zeros_ih = jnp.zeros((IN_DIM, HIDDEN), jnp.float32)
    dw1 = gemm(x.T, dh, zeros_ih)  # offload 5 (offload 6 is folded: x.T
    db1 = jnp.sum(dh, axis=0)  # reuse makes the 6th GEMM a reduction)

    lr = jnp.float32(LEARNING_RATE)
    return (
        w1 - lr * dw1,
        b1 - lr * db1,
        w2 - lr * dw2,
        b2 - lr * db2,
        loss,
    )


def predict(w1, b1, w2, b2, x):
    """Inference pass (2 offloads), returns class ids."""
    logits, _, _ = forward(w1, b1, w2, b2, x)
    return jnp.argmax(logits, axis=-1)


def spiral_batch(seed: int, batch: int = BATCH):
    """The synthetic workload: a 4-arm spiral embedded in IN_DIM features
    (2 informative + noise), the classic tiny-classifier benchmark."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, CLASSES, size=batch)
    t = rng.random(batch) * 2.0 + 0.5
    theta = labels * (2 * np.pi / CLASSES) + t * 0.8
    xy = np.stack([t * np.cos(theta), t * np.sin(theta)], axis=1)
    feats = np.concatenate(
        [xy, rng.standard_normal((batch, IN_DIM - 2)) * 0.02], axis=1
    ).astype(np.float32)
    onehot = np.eye(CLASSES, dtype=np.float32)[labels]
    return feats, onehot, labels
