"""Layer-1 Pallas kernel: the RedMulE GEMM hot-spot.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): RedMulE is an ASIC
array of L×H cascaded FP16 FMAs that keeps output tiles *stationary* in
per-row accumulator registers, streams X row-wise with maximal reuse, and
broadcasts W column-wise. On a TPU-shaped memory hierarchy the same
dataflow becomes:

* **output-stationary VMEM tiles** — the accumulator registers' analogue.
  One grid cell owns one (TILE_M × TILE_K) Z tile held in VMEM registers
  for the whole inner loop;
* a **`fori_loop` over the inner dimension n** — the paper's P-deep FMA
  pipeline sweeping one dot-product term per cycle, with a
  **round-to-binary16 after every step**, the exact numeric contract of
  the hardware's single-rounded FP16 FMA chain;
* **BlockSpecs** expressing the HBM↔VMEM schedule the RTL implements with
  its streamer: X blocks re-used along the K grid axis (row-wise reuse),
  W blocks re-fetched per K tile (column broadcast), Y/Z blocks touched
  once.

Values travel as f32 *carriers* of FP16 values at the PJRT boundary
(conversion is exact both ways); the in-kernel accumulator is f64. Each
FMA step computes `x*w + acc` in f64 and rounds the result to binary16:
the FP16 product is exact (22 bits), the f64 add keeps 53 bits >= 22 +
11 + 2, so rounding f64 -> f16 equals the hardware's single-rounded FMA.
(f32 would NOT be enough: 24 < 35 — double rounding through f32 provably
diverges, and the pytest sweep catches it on long chains.)

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO, which is exactly what
the Rust runtime loads. The real-TPU tiling story (VMEM footprint, MXU
utilization) is analytic — DESIGN.md §7.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes mirroring the paper instance: TILE_M = L = 12 rows,
# TILE_K = D = H*P = 12 in-flight output columns per row.
TILE_M = 12
TILE_K = 12


def _fp16_round(v: jnp.ndarray) -> jnp.ndarray:
    """Round an f64 carrier to binary16, staying in f64."""
    return v.astype(jnp.float16).astype(jnp.float64)


def _gemm_kernel(x_ref, w_ref, y_ref, z_ref, *, n: int):
    """One output tile: Z = Y + X·W with per-step FP16 rounding.

    x_ref: (tm, n) f32    — this row tile's X panel (row-wise reuse)
    w_ref: (n, tk) f32    — this column tile's W panel (broadcast)
    y_ref/z_ref: (tm, tk) — output-stationary accumulator tile
    """
    acc = y_ref[...].astype(jnp.float64)
    xs = x_ref[...].astype(jnp.float64)
    ws = w_ref[...].astype(jnp.float64)

    def step(t, acc):
        xt = jax.lax.dynamic_slice_in_dim(xs, t, 1, axis=1)  # (tm, 1)
        wt = jax.lax.dynamic_slice_in_dim(ws, t, 1, axis=0)  # (1, tk)
        return _fp16_round(xt * wt + acc)

    acc = jax.lax.fori_loop(0, n, step, acc)
    z_ref[...] = acc.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_k"))
def redmule_gemm(x, w, y, *, tile_m: int = TILE_M, tile_k: int = TILE_K):
    """FP16 GEMM `Z = Y + X·W` in RedMulE's accumulation order.

    Inputs/outputs are f32 carriers of FP16 values. Shapes:
    x (m, n), w (n, k), y (m, k) → z (m, k).
    """
    m, n = x.shape
    n2, k = w.shape
    assert n == n2 and y.shape == (m, k)
    tm = min(tile_m, m)
    tk = min(tile_k, k)

    grid = (pl.cdiv(m, tm), pl.cdiv(k, tk))
    return pl.pallas_call(
        functools.partial(_gemm_kernel, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, n), lambda i, j: (i, 0)),  # X: reused along j
            pl.BlockSpec((n, tk), lambda i, j: (0, j)),  # W: broadcast along i
            pl.BlockSpec((tm, tk), lambda i, j: (i, j)),  # Y
        ],
        out_specs=pl.BlockSpec((tm, tk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=True,
    )(x, w, y)


def _gemm_redundant_kernel(x_ref, w_ref, y_ref, z_ref, flag_ref, *, n: int):
    """FT-mode tile: the §3.1 consecutive-row duplication as tile
    duplication — two independent accumulations of the same tile plus an
    equality check, the same 2× compute-for-detection trade the hardware
    makes. `flag_ref` accumulates the number of mismatching elements."""
    acc_a = y_ref[...].astype(jnp.float64)
    acc_b = y_ref[...].astype(jnp.float64)
    xs = x_ref[...].astype(jnp.float64)
    ws = w_ref[...].astype(jnp.float64)

    def step(t, accs):
        a, b = accs
        xt = jax.lax.dynamic_slice_in_dim(xs, t, 1, axis=1)
        wt = jax.lax.dynamic_slice_in_dim(ws, t, 1, axis=0)
        # Primary and replica rows: same data, independent FMA chains.
        a = _fp16_round(xt * wt + a)
        b = _fp16_round(wt * xt + b)
        return (a, b)

    acc_a, acc_b = jax.lax.fori_loop(0, n, step, (acc_a, acc_b))
    # Edge tiles are padded; pad lanes may read NaN, and NaN != NaN would
    # count as a mismatch. Two NaNs compare equal for the checker (a real
    # single-copy NaN corruption still trips `!=`).
    neq = (acc_a != acc_b) & ~(jnp.isnan(acc_a) & jnp.isnan(acc_b))
    z_ref[...] = acc_a.astype(jnp.float32)
    flag_ref[0, 0] = jnp.sum(neq.astype(jnp.float32))


@jax.jit
def redmule_gemm_redundant(x, w, y):
    """FT-mode GEMM: returns (z, mismatch_count). A non-zero count is the
    checker's detection signal (always 0 without injected faults)."""
    m, n = x.shape
    _, k = w.shape
    tm = min(TILE_M, m)
    tk = min(TILE_K, k)
    grid = (pl.cdiv(m, tm), pl.cdiv(k, tk))
    z, flags = pl.pallas_call(
        functools.partial(_gemm_redundant_kernel, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, n), lambda i, j: (i, 0)),
            pl.BlockSpec((n, tk), lambda i, j: (0, j)),
            pl.BlockSpec((tm, tk), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tm, tk), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((grid[0], grid[1]), jnp.float32),
        ],
        interpret=True,
    )(x, w, y)
    return z, jnp.sum(flags)
