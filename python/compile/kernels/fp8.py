"""FP8 (E4M3 / E5M2) quantization — the hybrid-precision input path.

RedMulE supports "either FP16 or hybrid FP8 formats" (§2.1 of the
RedMulE-FT paper; the RedMulE paper details the widening CEs): X and W
arrive as 8-bit floats and are widened to FP16 at the compute elements,
while accumulation stays FP16. The JAX side of that contract is this
quantizer: it snaps values onto the exact FP8 grid (round-to-nearest-even,
saturating), so a GEMM on quantized inputs is bit-identical to a GEMM on
true 8-bit storage — the Rust side implements the same grids in
`rust/src/fp/fp8.rs` and the two are cross-checked through the
`gemm_fp8_*` artifact.

Formats follow the OCP/FN conventions used by FPnew:
  * E4M3: 4 exponent bits (bias 7), 3 mantissa bits, max 448, no inf
    (we saturate to ±448 and reserve NaN).
  * E5M2: 5 exponent bits (bias 15), 2 mantissa bits, max 57344,
    IEEE-style inf/NaN.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

FORMATS = ("e4m3", "e5m2")


def _spec(fmt: str):
    if fmt == "e4m3":
        # (mantissa bits, exponent bias, max finite)
        return 3, 7, 448.0
    if fmt == "e5m2":
        return 2, 15, 57344.0
    raise ValueError(f"unknown FP8 format {fmt!r}")


def quantize_fp8(v, fmt: str = "e4m3"):
    """Snap an f32/f16-valued array onto the FP8 grid (RTNE, saturating).

    Works under both numpy and jax.numpy inputs; returns the same backing
    library's array in float32.
    """
    m_bits, bias, max_fin = _spec(fmt)
    xp = jnp if isinstance(v, jnp.ndarray) else np
    v = v.astype(xp.float32)
    sign = xp.sign(v)
    mag = xp.abs(v)

    # Exponent of the FP8 binade, clamped at the subnormal floor.
    min_exp = 1 - bias  # smallest normal exponent
    e = xp.floor(xp.log2(xp.where(mag > 0, mag, 1.0)))
    e = xp.clip(e, min_exp, None)
    # Quantization step within the binade (subnormals share min_exp's).
    step = xp.exp2(e - m_bits)
    q = xp.round(mag / step)
    # Round-half-to-even: xp.round implements banker's rounding in numpy
    # and jax alike.
    snapped = q * step
    # Renormalize if rounding crossed into the next binade (e.g. 1.9375
    # -> 2.0): the representation is still exact, no re-rounding needed.
    snapped = xp.where(mag > 0, snapped, 0.0)
    # Saturate (E4M3 has no infinity; E5M2 saturates here too because the
    # hardware's widening path treats overflow as max-magnitude).
    snapped = xp.minimum(snapped, max_fin)
    return (sign * snapped).astype(xp.float32)


def fp8_grid(fmt: str = "e4m3") -> np.ndarray:
    """Every non-negative representable FP8 value (for tests)."""
    m_bits, bias, max_fin = _spec(fmt)
    vals = {0.0}
    # Subnormals: e = 1 - bias, mantissa 1..2^m-1.
    for m in range(1, 1 << m_bits):
        vals.add(m * 2.0 ** (1 - bias - m_bits))
    # Normals.
    e = 1 - bias
    while True:
        for m in range(1 << m_bits):
            x = (1.0 + m / (1 << m_bits)) * 2.0**e
            if x > max_fin:
                return np.array(sorted(vals), dtype=np.float64)
            vals.add(x)
        e += 1
