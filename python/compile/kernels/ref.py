"""Pure-numpy correctness oracle for the RedMulE GEMM kernels.

The contract shared by the hardware model (``rust/src/golden``), the Pallas
kernel (:mod:`compile.kernels.redmule`) and this oracle is:

    Z[m, k] = fp16-FMA-chain over ascending n of
              (X[m, n] * W[n, k]) accumulated onto Y[m, k]

with a **single round-to-nearest-even to binary16 per FMA step**. The
oracle implements each step in ``float64``: the FP16 product is exact in
f64, the addition rounds once to f64 (53 bits), and the cast to f16 rounds
again — by Figueroa's innocuous-double-rounding theorem (53 >= 2*11 + 2)
the pair equals one direct rounding, so this loop is bit-identical to a
true single-rounded FP16 FMA without having implemented one.

Everything here is deliberately independent of JAX so that a bug in the
kernel and a bug in the oracle cannot share a root cause.
"""

from __future__ import annotations

import numpy as np


def gemm_ref_exact(x: np.ndarray, w: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Bit-exact reference: FP16 single-rounded FMA chain, ascending n.

    Args:
        x: (m, n) float16
        w: (n, k) float16
        y: (m, k) float16

    Returns:
        (m, k) float16, bit-exact to the hardware accumulation order.
    """
    x = np.asarray(x, dtype=np.float16)
    w = np.asarray(w, dtype=np.float16)
    y = np.asarray(y, dtype=np.float16)
    m, n = x.shape
    n2, k = w.shape
    assert n == n2, f"inner dims disagree: {n} vs {n2}"
    assert y.shape == (m, k)

    # Vectorized over (m, k); sequential (ordered) over n.
    acc = y.astype(np.float64)
    xf = x.astype(np.float64)
    wf = w.astype(np.float64)
    for t in range(n):
        step = xf[:, t : t + 1] * wf[t : t + 1, :] + acc  # product exact, one f64 rounding
        acc = step.astype(np.float16).astype(np.float64)  # innocuous 2nd rounding
    return acc.astype(np.float16)


def gemm_ref_f64(x: np.ndarray, w: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Loose reference: full-precision matmul, rounded once at the end.

    Not bit-comparable to the hardware order (FP16 accumulation is not
    associative) — used for `allclose` sanity bounds only.
    """
    zf = (
        np.asarray(y, dtype=np.float64)
        + np.asarray(x, dtype=np.float64) @ np.asarray(w, dtype=np.float64)
    )
    return zf.astype(np.float16)


def random_fp16(shape, seed: int, mag: float = 1.0) -> np.ndarray:
    """Uniform FP16 values in [-mag, mag] — the campaign's workload
    distribution (well-conditioned for FP16 accumulation)."""
    rng = np.random.default_rng(seed)
    return ((rng.random(shape) * 2.0 - 1.0) * mag).astype(np.float16)
