"""AOT compilation: lower the Layer-2 graphs to HLO text artifacts.

Run once by `make artifacts`:

    cd python && python -m compile.aot --out-dir ../artifacts

Produces, under the output directory:

* `gemm_<m>x<n>x<k>.hlo.txt`        — RedMulE GEMM (Pallas kernel inside)
* `gemm_redundant_<m>x<n>x<k>.hlo.txt` — FT-mode duplicated GEMM + checker
* `mlp_train.hlo.txt`               — full TinyML train step (6 offloads)
* `mlp_predict.hlo.txt`             — inference pass
* `manifest.txt`                    — `name kind file param*` per line,
                                       parsed by `rust/src/runtime`

Interchange is **HLO text**, not `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which xla_extension 0.5.1
(the version the published `xla` crate binds) rejects with
`proto.id() <= INT_MAX`. The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and aot_recipe.md).

`jax_enable_x64` is required: the kernel's FMA chain accumulates in f64
(53 bits >= 22 + 11 + 2) so each step is a true single-rounded FP16 FMA —
f32 would double-round (see kernels/redmule.py).
"""

from __future__ import annotations

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402
from compile.kernels.fp8 import quantize_fp8  # noqa: E402
from compile.kernels.redmule import redmule_gemm, redmule_gemm_redundant  # noqa: E402

# GEMM shapes to export: the paper's fault-injection workload plus the
# shapes the examples use.
GEMM_SHAPES = [
    (12, 16, 16),  # Table-1 campaign workload
    (16, 16, 16),  # quickstart
    (48, 96, 96),  # perf-mode comparison workload
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_gemm(m: int, n: int, k: int, redundant: bool):
    spec_x = jax.ShapeDtypeStruct((m, n), jnp.float32)
    spec_w = jax.ShapeDtypeStruct((n, k), jnp.float32)
    spec_y = jax.ShapeDtypeStruct((m, k), jnp.float32)
    fn = redmule_gemm_redundant if redundant else redmule_gemm

    def tupled(x, w, y):
        out = fn(x, w, y)
        return out if isinstance(out, tuple) else (out,)

    return jax.jit(tupled).lower(spec_x, spec_w, spec_y)


def lower_gemm_fp8(m: int, n: int, k: int, fmt: str):
    """Hybrid-FP8 GEMM (§2.1): X and W snap onto the FP8 grid before the
    FP16 accumulation — the widening-CE input path, in-graph."""
    spec_x = jax.ShapeDtypeStruct((m, n), jnp.float32)
    spec_w = jax.ShapeDtypeStruct((n, k), jnp.float32)
    spec_y = jax.ShapeDtypeStruct((m, k), jnp.float32)

    def fn(x, w, y):
        return (redmule_gemm(quantize_fp8(x, fmt), quantize_fp8(w, fmt), y),)

    return jax.jit(fn).lower(spec_x, spec_w, spec_y)


def lower_mlp_train():
    specs = (
        jax.ShapeDtypeStruct((model.IN_DIM, model.HIDDEN), jnp.float32),
        jax.ShapeDtypeStruct((model.HIDDEN,), jnp.float32),
        jax.ShapeDtypeStruct((model.HIDDEN, model.CLASSES), jnp.float32),
        jax.ShapeDtypeStruct((model.CLASSES,), jnp.float32),
        jax.ShapeDtypeStruct((model.BATCH, model.IN_DIM), jnp.float32),
        jax.ShapeDtypeStruct((model.BATCH, model.CLASSES), jnp.float32),
    )
    return jax.jit(model.train_step).lower(*specs)


def lower_mlp_predict():
    specs = (
        jax.ShapeDtypeStruct((model.IN_DIM, model.HIDDEN), jnp.float32),
        jax.ShapeDtypeStruct((model.HIDDEN,), jnp.float32),
        jax.ShapeDtypeStruct((model.HIDDEN, model.CLASSES), jnp.float32),
        jax.ShapeDtypeStruct((model.CLASSES,), jnp.float32),
        jax.ShapeDtypeStruct((model.BATCH, model.IN_DIM), jnp.float32),
    )

    def tupled(w1, b1, w2, b2, x):
        return (model.predict(w1, b1, w2, b2, x).astype(jnp.float32),)

    return jax.jit(tupled).lower(*specs)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest: list[str] = ["# name kind file param*  (see rust/src/runtime/mod.rs)"]

    def emit(name: str, kind: str, lowered, params: list[int]):
        fname = f"{name}.hlo.txt"
        text = to_hlo_text(lowered)
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest.append(f"{name} {kind} {fname} {' '.join(map(str, params))}".rstrip())
        print(f"  {fname}: {len(text)} chars")

    for m, n, k in GEMM_SHAPES:
        emit(f"gemm_{m}x{n}x{k}", "gemm", lower_gemm(m, n, k, False), [m, n, k])
    m, n, k = GEMM_SHAPES[0]
    emit(
        f"gemm_redundant_{m}x{n}x{k}",
        "gemm_redundant",
        lower_gemm(m, n, k, True),
        [m, n, k],
    )
    for fmt in ("e4m3", "e5m2"):
        emit(
            f"gemm_fp8_{fmt}_{m}x{n}x{k}",
            f"gemm_fp8_{fmt}",
            lower_gemm_fp8(m, n, k, fmt),
            [m, n, k],
        )
    emit(
        "mlp_train",
        "mlp_train",
        lower_mlp_train(),
        [model.BATCH, model.IN_DIM, model.HIDDEN, model.CLASSES],
    )
    emit(
        "mlp_predict",
        "mlp_predict",
        lower_mlp_predict(),
        [model.BATCH, model.IN_DIM, model.HIDDEN, model.CLASSES],
    )

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
